"""Execute the fenced ``python`` code blocks of markdown files.

CI runs this over README.md and docs/*.md so the documentation can't rot:
every ```` ```python ```` block must run (blocks within one file share a
namespace, in order, like a REPL session).  Blocks fenced as
```` ```python no-run ```` are illustrative only and are skipped.

Usage: PYTHONPATH=src python tools/check_doc_snippets.py README.md docs/*.md
"""
from __future__ import annotations

import re
import sys

FENCE = re.compile(r"^```(\S*)[ \t]*(.*)$")


def blocks(text: str):
    """Yield (start_line, info, args, source) per fenced code block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE.match(lines[i])
        if m and m.group(1):
            start = i + 1
            body = []
            i += 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            yield start, m.group(1), m.group(2).strip(), "\n".join(body)
        i += 1


def check_file(path: str) -> tuple[int, int]:
    """Run a file's python blocks in one shared namespace; returns
    (ran, skipped).  Raises on the first failing block."""
    with open(path) as f:
        text = f.read()
    ns: dict = {"__name__": f"doc_snippet:{path}"}
    ran = skipped = 0
    for line, info, args, src in blocks(text):
        if info != "python":
            continue
        if "no-run" in args:
            skipped += 1
            continue
        print(f"  {path}:{line} ({len(src.splitlines())} lines)", flush=True)
        try:
            exec(compile(src, f"{path}:{line}", "exec"), ns)
        except Exception:
            print(f"FAILED snippet at {path}:{line}:\n{src}", file=sys.stderr)
            raise
        ran += 1
    return ran, skipped


def main(paths: list[str]) -> int:
    if not paths:
        print("usage: check_doc_snippets.py FILE.md [FILE.md ...]",
              file=sys.stderr)
        return 2
    total = skipped = 0
    for path in paths:
        print(f"checking {path}", flush=True)
        r, s = check_file(path)
        total += r
        skipped += s
    print(f"OK: {total} snippet(s) executed, {skipped} skipped (no-run)")
    if total == 0:
        print("error: no runnable snippets found", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
