"""Shared per-module analyses for ftlint rules.

``ModuleCtx`` wraps a parsed module and lazily computes:

  * import alias resolution (``jnp`` -> ``jax.numpy``,
    ``pl`` -> ``jax.experimental.pallas``, ``from jax import random`` ->
    ``jax.random`` ...), so rules match call targets by canonical dotted
    name regardless of local import style;
  * parent links and enclosing-scope qualnames for findings;
  * *traced-code* detection: the set of function nodes whose bodies run
    under a JAX trace — jit-decorated functions, functions passed to
    ``jax.jit`` / ``jax.vmap`` / ``jax.grad`` / ``jax.pmap``, bodies handed
    to ``jax.lax`` control-flow combinators (``scan`` / ``while_loop`` /
    ``fori_loop`` / ``cond`` / ``switch``), Pallas kernel bodies, and
    everything lexically nested inside any of those.

Traced-code detection is deliberately intraprocedural-plus-names: a local
function whose *name* is later wrapped (``self._step = jax.jit(_step)``)
is traced; calls across modules are not chased.  That is the right
trade-off for a blocking linter — no false positives from dynamic
dispatch, and the repo's jit wrapping is overwhelmingly local.
"""
from __future__ import annotations

import ast
import dataclasses
from functools import cached_property

FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)

# jax entry points whose function-valued arguments are traced
_TRACING_WRAPPERS = {
    "jax.jit", "jax.vmap", "jax.pmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat", "jax.linearize", "jax.vjp", "jax.jvp",
    "jax.experimental.shard_map.shard_map", "jax.shard_map",
}
_LAX_COMBINATORS = {
    "jax.lax.scan", "jax.lax.while_loop", "jax.lax.fori_loop",
    "jax.lax.cond", "jax.lax.switch", "jax.lax.map",
    "jax.lax.associative_scan", "jax.lax.custom_root",
}
_PALLAS_CALLS = {"jax.experimental.pallas.pallas_call"}
_JIT_DECORATORS = {"jax.jit", "jax.pmap"}


@dataclasses.dataclass
class ModuleCtx:
    tree: ast.Module
    source: str
    path: str

    # ------------------------------------------------------------ imports --
    @cached_property
    def aliases(self) -> dict[str, str]:
        """Local name -> canonical dotted prefix."""
        out: dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    out[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    if a.name == "*":
                        continue
                    out[a.asname or a.name] = f"{node.module}.{a.name}"
        return out

    def dotted(self, node: ast.AST) -> str | None:
        """Canonical dotted name of a Name/Attribute chain, with the root
        resolved through the module's import aliases."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.aliases.get(node.id, node.id)
        return ".".join([root] + list(reversed(parts)))

    def call_target(self, call: ast.Call) -> str | None:
        return self.dotted(call.func)

    # ------------------------------------------------------------ parents --
    @cached_property
    def parents(self) -> dict[ast.AST, ast.AST]:
        out: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                out[child] = node
        return out

    def scope_of(self, node: ast.AST) -> str:
        """Qualname of the innermost enclosing function ("<module>" if none)."""
        names: list[str] = []
        cur = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                names.append(cur.name)
            elif isinstance(cur, ast.ClassDef):
                names.append(cur.name)
            cur = self.parents.get(cur)
        return ".".join(reversed(names)) or "<module>"

    def enclosing_function(self, node: ast.AST):
        cur = self.parents.get(node)
        while cur is not None and not isinstance(cur, FUNC_NODES):
            cur = self.parents.get(cur)
        return cur

    # ------------------------------------------------------- traced code ---
    def _is_jit_decorator(self, dec: ast.AST) -> bool:
        name = self.dotted(dec)
        if name in _JIT_DECORATORS:
            return True
        if isinstance(dec, ast.Call):
            target = self.call_target(dec)
            if target in _JIT_DECORATORS:
                return True
            # functools.partial(jax.jit, ...) / partial(jax.jit, ...)
            if target in ("functools.partial", "partial") and dec.args:
                return self.dotted(dec.args[0]) in _JIT_DECORATORS
        return False

    @cached_property
    def traced_functions(self) -> set[ast.AST]:
        """Function nodes whose bodies execute under a JAX trace."""
        roots: set[ast.AST] = set()
        defs_by_name: dict[str, list[ast.AST]] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs_by_name.setdefault(node.name, []).append(node)
                if any(self._is_jit_decorator(d) for d in node.decorator_list):
                    roots.add(node)

        def mark_func_arg(arg: ast.AST):
            if isinstance(arg, FUNC_NODES):
                roots.add(arg)
            elif isinstance(arg, ast.Name):
                for d in defs_by_name.get(arg.id, []):
                    roots.add(d)
            elif isinstance(arg, ast.Call):
                # functools.partial(body, ...) wrapping a kernel body
                target = self.call_target(arg)
                if target in ("functools.partial", "partial") and arg.args:
                    mark_func_arg(arg.args[0])

        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            target = self.call_target(node)
            if target in _TRACING_WRAPPERS and node.args:
                mark_func_arg(node.args[0])
            elif target in _LAX_COMBINATORS:
                for a in node.args:
                    mark_func_arg(a)
            elif target in _PALLAS_CALLS and node.args:
                mark_func_arg(node.args[0])

        # everything lexically nested in a traced function is traced
        traced: set[ast.AST] = set()
        for root in roots:
            for sub in ast.walk(root):
                if isinstance(sub, FUNC_NODES) or sub is root:
                    traced.add(sub)
        return traced

    def in_traced_code(self, node: ast.AST) -> bool:
        cur: ast.AST | None = node
        while cur is not None:
            if cur in self.traced_functions:
                return True
            cur = self.parents.get(cur)
        return False
