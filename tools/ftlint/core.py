"""ftlint rule engine: findings, suppressions, baseline, file walking.

A *rule* is an object with ``code``, ``name``, ``invariant`` and a
``check(ctx) -> list[Finding]`` method; ``ctx`` is a :class:`ModuleCtx`
carrying the parsed AST plus shared analyses (import aliases, traced-code
detection — see ``tools.ftlint.jaxctx``).

Suppression contract: a finding on line N is suppressed by an inline
comment on that line (or on the line directly above, when the marker is
the whole line)::

    y = risky_thing()  # ftlint: disable=FTL001 -- why this is sound

The justification after ``--`` is mandatory: a bare ``disable`` is itself
reported (as FTL000) so waivers stay reviewable.

Baseline contract: ``tools/ftlint/baseline.txt`` holds grandfathered
findings as ``CODE path::scope::message`` lines (line numbers excluded so
unrelated edits don't invalidate entries).  The goal state is an empty
baseline; CI uploads the full report so drift is visible.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import re
import sys
from pathlib import Path

from tools.ftlint.jaxctx import ModuleCtx

SUPPRESS_RE = re.compile(
    r"#\s*ftlint:\s*disable=([A-Z0-9,\s]+?)\s*(?:--\s*(\S.*))?$")


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str          # FTLxxx
    path: str          # repo-relative posix path
    line: int          # 1-indexed
    col: int
    scope: str         # enclosing function qualname ("<module>" at top level)
    message: str

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.code} "
                f"[{self.scope}] {self.message}")

    def baseline_key(self) -> str:
        return f"{self.code} {self.path}::{self.scope}::{self.message}"


# ----------------------------------------------------------- suppressions --
def _suppressions(source: str) -> dict[int, tuple[set[str], str | None]]:
    """line -> (set of disabled codes, justification or None)."""
    out: dict[int, tuple[set[str], str | None]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = SUPPRESS_RE.search(text)
        if not m:
            continue
        codes = {c.strip() for c in m.group(1).split(",") if c.strip()}
        out[i] = (codes, m.group(2))
        # a marker-only line covers the next line of code
        if text.strip().startswith("#"):
            out[i + 1] = (codes, m.group(2))
    return out


def _apply_suppressions(findings: list[Finding], source: str,
                        path: str) -> list[Finding]:
    sup = _suppressions(source)
    kept: list[Finding] = []
    used: set[int] = set()
    for f in findings:
        entry = sup.get(f.line)
        if entry and (f.code in entry[0] or "ALL" in entry[0]):
            used.add(f.line)
            if not entry[1]:
                kept.append(Finding(
                    "FTL000", path, f.line, f.col, f.scope,
                    f"suppression of {f.code} lacks a justification "
                    "(write '# ftlint: disable=CODE -- reason')"))
            continue
        kept.append(f)
    return kept


# ---------------------------------------------------------------- linting --
def lint_source(source: str, path: str = "<string>",
                rules=None) -> list[Finding]:
    """Lint one module's source text.  Syntax errors are reported as FTL000
    rather than crashing the whole run."""
    from tools.ftlint.rules import ALL_RULES
    rules = ALL_RULES if rules is None else rules
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("FTL000", path, e.lineno or 1, e.offset or 0,
                        "<module>", f"syntax error: {e.msg}")]
    ctx = ModuleCtx(tree=tree, source=source, path=path)
    findings: list[Finding] = []
    for rule in rules:
        findings.extend(rule.check(ctx))
    findings.sort(key=lambda f: (f.line, f.col, f.code))
    return _apply_suppressions(findings, source, path)


def lint_file(fp: Path, root: Path, rules=None) -> list[Finding]:
    rel = fp.resolve().relative_to(root.resolve()).as_posix() \
        if fp.resolve().is_relative_to(root.resolve()) else fp.as_posix()
    try:
        source = fp.read_text()
    except OSError as e:
        # a path that raced away mid-run (or a stale explicit argument)
        # shouldn't take down the whole lint — its baseline entries will
        # surface as stale instead
        print(f"[ftlint] warning: cannot read {rel}: {e.strerror or e}",
              file=sys.stderr)
        return []
    return lint_source(source, rel, rules)


def iter_py_files(paths: list[str], root: Path):
    for p in paths:
        fp = (root / p) if not Path(p).is_absolute() else Path(p)
        if fp.is_dir():
            yield from sorted(fp.rglob("*.py"))
        elif fp.suffix == ".py":
            if not fp.exists():
                print(f"[ftlint] warning: no such file: {p}",
                      file=sys.stderr)
                continue
            yield fp


def lint_paths(paths: list[str], root: Path | None = None,
               rules=None) -> list[Finding]:
    root = root or Path.cwd()
    findings: list[Finding] = []
    for fp in iter_py_files(paths, root):
        findings.extend(lint_file(fp, root, rules))
    return findings


# --------------------------------------------------------------- baseline --
def load_baseline(path: Path) -> set[str]:
    if not path.exists():
        return set()
    out = set()
    for line in path.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            out.add(line)
    return out


def split_baselined(findings: list[Finding],
                    baseline: set[str]) -> tuple[list[Finding], list[Finding]]:
    new, old = [], []
    for f in findings:
        (old if f.baseline_key() in baseline else new).append(f)
    return new, old


# -------------------------------------------------------------------- CLI --
def main(argv=None) -> int:
    from tools.ftlint.rules import ALL_RULES
    ap = argparse.ArgumentParser(
        prog="python -m tools.ftlint",
        description="Static analysis for the repo's fault-tolerance "
                    "correctness contracts (see docs/ftlint.md).")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint")
    ap.add_argument("--baseline",
                    default=str(Path(__file__).parent / "baseline.txt"),
                    help="grandfathered-findings file")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report baselined findings as errors too")
    ap.add_argument("--write-report", metavar="PATH",
                    help="write a JSON report (CI artifact)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in ALL_RULES:
            print(f"{r.code}  {r.name}")
            print(f"        invariant: {r.invariant}")
        return 0

    root = Path.cwd()
    findings = lint_paths(args.paths, root)
    baseline = set() if args.no_baseline else load_baseline(
        Path(args.baseline))
    new, old = split_baselined(findings, baseline)

    for f in new:
        print(f.render())
    if old:
        print(f"[ftlint] {len(old)} baselined finding(s) not shown "
              f"(--no-baseline to list)", file=sys.stderr)
    stale = baseline - {f.baseline_key() for f in findings}
    if stale:
        print(f"[ftlint] note: {len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} (fixed or moved — "
              "prune tools/ftlint/baseline.txt)", file=sys.stderr)

    if args.write_report:
        def row(f: Finding) -> dict:
            # include the baseline key verbatim: report consumers were
            # reconstructing it from (code, path, scope, message) and
            # drifting from baseline.txt whenever the key format changed
            d = dataclasses.asdict(f)
            d["key"] = f.baseline_key()
            return d
        report = {
            "new": [row(f) for f in new],
            "baselined": [row(f) for f in old],
            "stale_baseline": sorted(stale),
        }
        Path(args.write_report).write_text(json.dumps(report, indent=2))

    n_files = len(list(iter_py_files(args.paths, root)))
    status = "clean" if not new else f"{len(new)} finding(s)"
    print(f"[ftlint] {n_files} files, {len(ALL_RULES)} rules: {status}",
          file=sys.stderr)
    return 1 if new else 0
