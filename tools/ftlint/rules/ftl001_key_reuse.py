"""FTL001 — PRNG key reuse.

Invariant: a ``jax.random`` key feeds at most one sampling sink; every
additional draw must go through a fresh derivation (``split`` /
``fold_in``).  Reusing a key replays the exact same fault pattern (or
sample) at two sites, which silently corrupts the fault-stream accounting
the paper's reliability numbers rest on — the PR 3 replayed-fault-draw bug
(back-to-back ``Engine.generate()`` calls re-drawing identical faults),
generalized.

Detection is an intraprocedural abstract interpretation per function
scope:

  * bindings: names assigned from key constructors/derivations
    (``PRNGKey`` / ``key`` / ``split`` / ``fold_in`` / ...) and key-named
    parameters;
  * sinks: ``jax.random`` samplers plus the repo's key-consuming entry
    points (``flip_bits``, ``inject_*_faults``, ``random_planes``,
    ``protect_linear``, ``vision_batch``, ...);
  * derivations never consume; ``if`` branches analyze independently and
    merge; a sink inside a loop on a key created outside it (and not
    re-derived per iteration) is the loop form of the same bug.

Only plain-``Name`` keys are tracked — subscripted key arrays
(``ks[i]``) are out of scope by design (index expressions vary per use).
"""
from __future__ import annotations

import ast
import dataclasses
import re

from tools.ftlint.jaxctx import FUNC_NODES, ModuleCtx
from tools.ftlint.rules import Rule

# jax.random entry points that *derive* keys rather than consuming them
DERIVATIONS = {"split", "fold_in", "PRNGKey", "key", "wrap_key_data",
               "key_data", "clone"}

# repo-local functions whose first positional key argument is a sink
CONSUMERS = {
    "flip_bits", "inject_output_faults", "inject_weight_faults",
    "random_planes", "protect_linear", "protect_linear_ste", "ft_linear",
    "vision_batch",
}

KEY_PARAM_RE = re.compile(r"(^k$|^k[0-9]+$|key|rng)", re.IGNORECASE)


@dataclasses.dataclass
class _Binding:
    depth: int                 # loop depth at (re)creation
    consumed_line: int | None = None

    def copy(self) -> "_Binding":
        return _Binding(self.depth, self.consumed_line)


class _Scope:
    def __init__(self, rule, ctx: ModuleCtx, func):
        self.rule, self.ctx = rule, ctx
        self.bindings: dict[str, _Binding] = {}
        self.depth = 0
        self.reported: set[str] = set()
        self.findings: list = []
        if isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = func.args
            for a in (args.posonlyargs + args.args + args.kwonlyargs
                      + [x for x in (args.vararg, args.kwarg) if x]):
                if KEY_PARAM_RE.search(a.arg):
                    self.bindings[a.arg] = _Binding(0)

    # ---------------------------------------------------------- classify --
    def _sink_call(self, call: ast.Call) -> bool:
        target = self.ctx.call_target(call)
        if target is None:
            return False
        head, _, last = target.rpartition(".")
        if head == "jax.random":
            return last not in DERIVATIONS
        return last in CONSUMERS or target in CONSUMERS

    def _derivation_call(self, node: ast.AST) -> bool:
        if not isinstance(node, ast.Call):
            return False
        target = self.ctx.call_target(node)
        return (target is not None and target.startswith("jax.random.")
                and target.rpartition(".")[2] in DERIVATIONS)

    # ----------------------------------------------------------- consume --
    def _consume(self, name_node: ast.Name):
        name = name_node.id
        b = self.bindings.get(name)
        if b is None:
            return
        if name in self.reported:
            return
        if b.consumed_line is not None:
            self.reported.add(name)
            self.findings.append(self.rule.finding(
                self.ctx, name_node,
                f"PRNG key '{name}' already consumed on line "
                f"{b.consumed_line} is consumed again — derive a fresh key "
                f"(jax.random.split / fold_in) before each draw"))
        elif b.depth < self.depth:
            self.reported.add(name)
            self.findings.append(self.rule.finding(
                self.ctx, name_node,
                f"PRNG key '{name}' created outside this loop is consumed "
                f"inside it — every iteration replays the same stream; "
                f"fold the loop index in (jax.random.fold_in)"))
        else:
            b.consumed_line = name_node.lineno

    def _visit_expr(self, node: ast.AST):
        """Find sink calls in an expression, skipping nested functions."""
        if isinstance(node, FUNC_NODES):
            return
        if isinstance(node, ast.Call):
            if self._sink_call(node):
                for arg in list(node.args) + [kw.value for kw in node.keywords]:
                    if isinstance(arg, ast.Name):
                        self._consume(arg)
            for child in ast.iter_child_nodes(node):
                self._visit_expr(child)
            return
        for child in ast.iter_child_nodes(node):
            self._visit_expr(child)

    # -------------------------------------------------------- statements --
    def _bind_targets(self, targets, fresh: bool):
        for t in targets:
            if isinstance(t, ast.Name):
                if fresh:
                    self.bindings[t.id] = _Binding(self.depth)
                    self.reported.discard(t.id)
                else:
                    self.bindings.pop(t.id, None)
            elif isinstance(t, (ast.Tuple, ast.List)):
                self._bind_targets(t.elts, fresh)

    def run(self, body):
        for stmt in body:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.stmt):
        if isinstance(stmt, FUNC_NODES) or isinstance(stmt, ast.ClassDef):
            return                       # nested scopes analyzed separately
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = stmt.value
            if value is not None:
                self._visit_expr(value)
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            self._bind_targets(targets, fresh=self._derivation_call(value))
            return
        if isinstance(stmt, ast.If):
            self._visit_expr(stmt.test)
            self._branch([stmt.body, stmt.orelse])
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._visit_expr(stmt.iter)
            self._bind_targets([stmt.target], fresh=False)
            self.depth += 1
            self.run(stmt.body)
            self.depth -= 1
            self.run(stmt.orelse)
            return
        if isinstance(stmt, ast.While):
            self._visit_expr(stmt.test)
            self.depth += 1
            self.run(stmt.body)
            self.depth -= 1
            self.run(stmt.orelse)
            return
        if isinstance(stmt, ast.Try):
            self._branch([stmt.body] + [h.body for h in stmt.handlers])
            self.run(stmt.orelse)
            self.run(stmt.finalbody)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._visit_expr(item.context_expr)
            self.run(stmt.body)
            return
        for child in ast.iter_child_nodes(stmt):
            self._visit_expr(child)

    def _branch(self, bodies):
        """Analyze alternative branches independently, then merge: a key is
        consumed after the If when any branch consumed it."""
        snapshot = {k: v.copy() for k, v in self.bindings.items()}
        merged: dict[str, _Binding] = {}
        for body in bodies:
            self.bindings = {k: v.copy() for k, v in snapshot.items()}
            self.run(body)
            for k, v in self.bindings.items():
                cur = merged.get(k)
                if cur is None:
                    merged[k] = v.copy()
                elif cur.consumed_line is None and v.consumed_line is not None:
                    merged[k] = v.copy()
        self.bindings = merged


class KeyReuseRule(Rule):
    code = "FTL001"
    name = "prng-key-reuse"
    invariant = ("every jax.random key feeds exactly one sink; reuse "
                 "replays fault/sample streams and corrupts reliability "
                 "accounting")

    def check(self, ctx: ModuleCtx):
        findings = []
        scopes = [(None, ctx.tree.body)]
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scopes.append((node, node.body))
        for func, body in scopes:
            scope = _Scope(self, ctx, func)
            scope.run(body)
            findings.extend(scope.findings)
        return findings


RULE = KeyReuseRule()
