"""FTL007 — global jax config mutations live in exactly one place.

Invariant: library code never calls ``jax.config.update``.  The repo's
bit-exactness contracts hang on process-global flags
(``jax_threefry_partitionable`` above all: flipping it changes every
random draw in the process), so the flags are pinned once, at
``repro.core.faults`` import, before anything traces.  A second update
site is a time bomb in either direction: run before the sanctioned pin it
silently loses; run after a trace was cached it changes the lowering for
*later* executables only — two halves of one run disagreeing on the PRNG
(the partition-variance bug class ftverify FTV102 checks at the IR level).

Tests and conftest files are exempt: flipping flags to *prove* a contract
breaks (e.g. the FTV102 revert fixture) is exactly what tests are for.
"""
from __future__ import annotations

import ast

from tools.ftlint.jaxctx import ModuleCtx
from tools.ftlint.rules import Rule

# the one sanctioned library update site
ALLOWED_SUFFIXES = ("core/faults.py",)


def _exempt_path(path: str) -> bool:
    p = path.replace("\\", "/")
    if any(p.endswith(sfx) for sfx in ALLOWED_SUFFIXES):
        return True
    parts = p.split("/")
    fname = parts[-1]
    return ("tests" in parts or fname.startswith("test_")
            or fname == "conftest.py")


class ConfigUpdateRule(Rule):
    code = "FTL007"
    name = "config-update-site"
    invariant = ("jax.config.update appears only in repro/core/faults.py "
                 "(and tests); all other code inherits the pinned flags")

    def check(self, ctx: ModuleCtx):
        if _exempt_path(ctx.path):
            return []
        findings = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            target = ctx.call_target(node)
            if target is None:
                continue
            if target == "jax.config.update" \
                    or target.endswith(".config.update"):
                flag = ""
                if node.args and isinstance(node.args[0], ast.Constant):
                    flag = f" ({node.args[0].value!r})"
                findings.append(self.finding(
                    ctx, node,
                    f"jax.config.update{flag} outside repro/core/faults.py: "
                    f"global flags are pinned once at the fault layer's "
                    f"import — a second site either loses the race or "
                    f"changes lowering mid-process (partition-variant PRNG, "
                    f"see docs/ftlint.md)"))
        return findings


RULE = ConfigUpdateRule()
