"""FTL003 — protection-policy pytree discipline.

Invariant (PR 1's core design): a ``ProtectionPolicy`` is a frozen
dataclass pytree whose *only* dynamic leaf is ``ber``.  Everything else —
layer structure, protection thresholds as metadata, seeds — is static, so
the jitted datapath specializes on the treedef and BER sweeps vmap/scan
over one executable.  Three ways code silently breaks this:

  * mutating a frozen policy via ``object.__setattr__`` outside the
    ``repro/ft`` package (bypasses ``tune()``'s field routing and the
    frozen contract);
  * registering a policy-like pytree with structural fields as data
    leaves (every structural field on the trace = recompile-per-design is
    gone AND cache keys collapse);
  * (re)building policies inside traced code — ``.tune()`` /
    ``dataclasses.replace`` / registry lookups — which rebuilds treedefs
    per trace and moves structural metadata toward traced positions.

The last class also covers "a structural field reaching a traced
position": a structural policy field fed directly into a ``jnp`` / ``lax``
array operation inside traced code is flagged (``ber``, and the sanctioned
``FTCtx.dyn`` locals, are exempt — those are the designed dynamic paths).
"""
from __future__ import annotations

import ast
import re

from tools.ftlint.jaxctx import ModuleCtx
from tools.ftlint.rules import Rule

# every ProtectionPolicy field except the dynamic leaf `ber`
STRUCTURAL_FIELDS = {
    "s_th", "s_policy", "q_scale",                       # AlgorithmLayer
    "recompute", "whole_layer_tmr", "temporal",          # ArchLayer
    "dot_size", "data_reuse",
    "ib_th", "nb_th", "pe_policy",                       # CircuitLayer
    "weight_faults", "seed", "name",                     # policy top level
}
POLICY_COMPONENTS = {"algorithm", "arch", "circuit"}
POLICY_NAME_RE = re.compile(r"(^|_)(policy|pol)(s|$|_)", re.IGNORECASE)
POLICY_BUILDERS = {"get_policy", "from_ftconfig"}
ALLOWED_PATHS = ("repro/ft/",)          # the package that owns the contract
ARRAY_NAMESPACES = ("jax.numpy.", "jax.lax.", "jnp.")


def _attr_chain(node: ast.Attribute) -> tuple[list[str], ast.AST]:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    return list(reversed(parts)), node


class PolicyPytreeRule(Rule):
    code = "FTL003"
    name = "policy-pytree-discipline"
    invariant = ("ProtectionPolicy pytrees keep ber as the only dynamic "
                 "leaf; structural fields stay static metadata and frozen "
                 "policies are only rebuilt via tune()/with_ber()")

    def check(self, ctx: ModuleCtx):
        findings = []
        in_ft = any(p in ctx.path for p in ALLOWED_PATHS)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            target = ctx.call_target(node)

            # (a) frozen-policy mutation outside repro/ft
            if target == "object.__setattr__" and not in_ft:
                findings.append(self.finding(
                    ctx, node,
                    "object.__setattr__ outside repro/ft: frozen "
                    "protection policies must be rebuilt with "
                    "policy.tune(...) / with_ber(...), never mutated"))
                continue

            # (b) policy pytree registration with structural data leaves
            if target in ("jax.tree_util.register_dataclass",
                          "jax.tree_util.register_pytree_node") and node.args:
                cls = node.args[0]
                cls_name = cls.id if isinstance(cls, ast.Name) else ""
                if "Policy" in cls_name:
                    data = next((kw.value for kw in node.keywords
                                 if kw.arg == "data_fields"), None)
                    if data is None and len(node.args) > 1:
                        data = node.args[1]
                    leaves = None
                    if isinstance(data, (ast.List, ast.Tuple)):
                        leaves = [e.value for e in data.elts
                                  if isinstance(e, ast.Constant)]
                    if leaves is not None and leaves != ["ber"]:
                        findings.append(self.finding(
                            ctx, node,
                            f"policy pytree registered with data leaves "
                            f"{leaves}: 'ber' must be the only dynamic "
                            f"leaf (structural fields belong in "
                            f"meta_fields)"))
                continue

            if not ctx.in_traced_code(node):
                continue

            # (c) policy (re)construction inside traced code
            last = (target or "").rpartition(".")[2]
            if last in POLICY_BUILDERS:
                findings.append(self.finding(
                    ctx, node,
                    f"'{last}' inside traced code: registry lookups "
                    f"rebuild policy objects/treedefs per trace — resolve "
                    f"the policy on the host and pass it in as a pytree"))
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr == "tune"):
                findings.append(self.finding(
                    ctx, node,
                    ".tune(...) inside traced code rebuilds the policy "
                    "treedef per trace (and a traced override of a "
                    "structural field would silently change the cache "
                    "key) — tune on the host, trace only ber/dyn"))
            elif target == "dataclasses.replace" and node.args:
                root = node.args[0]
                root_name = root.id if isinstance(root, ast.Name) else ""
                if POLICY_NAME_RE.search(root_name):
                    findings.append(self.finding(
                        ctx, node,
                        f"dataclasses.replace({root_name}, ...) inside "
                        f"traced code rebuilds the policy structure per "
                        f"trace — use with_ber/dyn for traced knobs"))

            # (d) structural field used as an array operand in traced code
            if target and target.startswith(ARRAY_NAMESPACES):
                for arg in ast.walk(node):
                    if not isinstance(arg, ast.Attribute) or arg is node.func:
                        continue
                    chain, root = _attr_chain(arg)
                    if chain[-1] not in STRUCTURAL_FIELDS:
                        continue
                    root_name = root.id if isinstance(root, ast.Name) else ""
                    policyish = (
                        any(c in POLICY_COMPONENTS for c in chain[:-1])
                        or POLICY_NAME_RE.search(root_name))
                    if policyish:
                        findings.append(self.finding(
                            ctx, arg,
                            f"structural policy field "
                            f"'{'.'.join([root_name] + chain)}' reaches a "
                            f"traced array position ({target}): only ber "
                            f"(or FTCtx.dyn overrides) may ride the "
                            f"trace — read structural fields into static "
                            f"Python values instead"))
        return findings


RULE = PolicyPytreeRule()
