"""FTL005 — Pallas kernel structural rules.

Invariant: every ``pl.pallas_call`` site in the repo follows the kernel
contract the three existing kernels (``qmatmul``, ``fault_inject``,
``protected_mm``) established, so the upcoming fused decode kernel
inherits the checks:

  * **divisibility guard** — BlockSpec block shapes must divide the
    operand shapes (an assert/raise on ``% block == 0``, or explicit
    padding before the call).  Pallas silently clips out-of-range blocks
    in some modes; the rolling-cache shape-drift bug from PR 3 was this
    class of silent misalignment.
  * **interpret-mode fallback** — the call must thread an ``interpret=``
    flag so the same program runs on CPU for the bit-exactness tests
    against ``ref.py``; a hardcoded compiled-only kernel is untestable in
    tier-1.
  * **memory/compute-space annotations** — ``compiler_params`` with
    ``dimension_semantics`` must be given (grid dims default to
    "arbitrary" = fully sequential otherwise), and every scratch buffer
    must name its memory space explicitly (``pltpu.VMEM(...)`` etc.).
"""
from __future__ import annotations

import ast

from tools.ftlint.jaxctx import ModuleCtx
from tools.ftlint.rules import Rule

MEMORY_SPACES = {"VMEM", "SMEM", "ANY", "SemaphoreType", "HBM", "CMEM"}


def _has_divisibility_guard(func: ast.AST) -> bool:
    for node in ast.walk(func):
        test = None
        if isinstance(node, ast.Assert):
            test = node.test
        elif isinstance(node, ast.If):
            # if x % b: raise / if x % b != 0: raise
            if any(isinstance(s, ast.Raise) for s in node.body):
                test = node.test
        if test is not None:
            for sub in ast.walk(test):
                if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod):
                    return True
        if isinstance(node, ast.Call):
            fn = node.func
            name = (fn.id if isinstance(fn, ast.Name)
                    else fn.attr if isinstance(fn, ast.Attribute) else "")
            if "pad" in name.lower():
                return True
    return False


class PallasRule(Rule):
    code = "FTL005"
    name = "pallas-kernel-contract"
    invariant = ("pallas_call sites guard BlockSpec divisibility, thread "
                 "an interpret-mode fallback, and annotate "
                 "memory/compute spaces explicitly")

    def check(self, ctx: ModuleCtx):
        findings = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if ctx.call_target(node) != "jax.experimental.pallas.pallas_call":
                continue
            kwargs = {kw.arg: kw.value for kw in node.keywords}

            if "interpret" not in kwargs:
                findings.append(self.finding(
                    ctx, node,
                    "pallas_call without an interpret= fallback: the "
                    "kernel cannot run under the CPU bit-exactness tests "
                    "against its ref.py oracle"))
            elif isinstance(kwargs["interpret"], ast.Constant):
                findings.append(self.finding(
                    ctx, node,
                    "pallas_call hardcodes interpret=<const>: thread a "
                    "caller-controlled flag so tests interpret and "
                    "deployments compile"))

            cp = kwargs.get("compiler_params")
            if cp is None:
                findings.append(self.finding(
                    ctx, node,
                    "pallas_call without compiler_params: grid "
                    "dimension_semantics default to sequential and the "
                    "compute-space contract is implicit"))
            elif isinstance(cp, ast.Call) and not any(
                    kw.arg == "dimension_semantics" for kw in cp.keywords):
                findings.append(self.finding(
                    ctx, cp,
                    "compiler_params without dimension_semantics: declare "
                    "which grid dims are parallel vs arbitrary"))

            scratch = kwargs.get("scratch_shapes")
            if isinstance(scratch, (ast.List, ast.Tuple)):
                for entry in scratch.elts:
                    space = ""
                    if isinstance(entry, ast.Call):
                        fn = entry.func
                        space = (fn.attr if isinstance(fn, ast.Attribute)
                                 else fn.id if isinstance(fn, ast.Name)
                                 else "")
                    if space not in MEMORY_SPACES:
                        findings.append(self.finding(
                            ctx, entry,
                            "scratch buffer without an explicit memory "
                            "space (pltpu.VMEM / SMEM / ...): placement "
                            "must not be left to the compiler default"))

            func = ctx.enclosing_function(node)
            if func is None or not _has_divisibility_guard(func):
                findings.append(self.finding(
                    ctx, node,
                    "no BlockSpec divisibility guard in the enclosing "
                    "function: assert operand shapes divide the block "
                    "shapes (or pad) — misaligned blocks fail silently "
                    "or clip"))
        return findings


RULE = PallasRule()
