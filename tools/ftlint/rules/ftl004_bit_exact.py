"""FTL004 — bit-exactness of the integer fault datapath.

Invariant: from quantization to the final rescale, the protected datapath
is integer-only (int8 operands, int32/24-bit saturating accumulate, bit
flips on two's-complement words).  That is what makes the Pallas kernels
bit-exactly testable against ``ref.py``, the batched DSE oracle
bit-identical to the looped path, and fault draws reproducible across
backends.  One stray float cast or true division inside the datapath
turns "bit-exact" into "close", and every parity test downstream goes
flaky at the epsilon level.

Scope: all functions in ``kernels/*/ref.py``, ``kernels/*/kernel.py`` and
``core/faults.py``, plus the named integer-datapath functions in
``ft/api.py``.  Exemptions encode the two sanctioned float boundaries:
statements that apply a quantization *scale* (``scale`` / ``sx`` / ``sw``)
and probability arithmetic (``ber`` / rates / thresholds) — probabilities
are float by nature; data words are not.

Also enforced here: integer matmuls must pin
``preferred_element_type=jnp.int32`` — without it the accumulator dtype is
backend-dependent, which is exactly the cross-backend drift the paper's
24-bit-accumulator model exists to prevent.
"""
from __future__ import annotations

import ast
import re

from tools.ftlint.jaxctx import FUNC_NODES, ModuleCtx
from tools.ftlint.rules import Rule

DATAPATH_FILE_RE = re.compile(
    r"(kernels/[^/]+/(ref|kernel)\.py|core/faults\.py)$")
# files where only named functions carry the integer-datapath contract
DATAPATH_FUNCS_BY_FILE = {
    "ft/api.py": {"_protect_reference"},
}

FLOAT_DTYPES = {
    "jax.numpy.float16", "jax.numpy.float32", "jax.numpy.float64",
    "jax.numpy.bfloat16", "numpy.float16", "numpy.float32",
    "numpy.float64", "float",
}
FLOAT_PRODUCERS = {
    "jax.numpy.mean", "jax.numpy.var", "jax.numpy.std", "jax.numpy.sqrt",
    "jax.numpy.exp", "jax.numpy.log", "jax.numpy.log2", "jax.numpy.sin",
    "jax.numpy.cos", "jax.numpy.tanh", "jax.numpy.true_divide",
    "jax.lax.rsqrt", "jax.nn.softmax",
}
INT_MATMULS = {"jax.numpy.matmul", "jax.numpy.dot", "jax.lax.dot_general",
               "jax.lax.dot"}
# sanctioned float contexts: quantization scales and probabilities
EXEMPT_NAME_RE = re.compile(
    r"(^|_)(scale|sx|sw|ber|p|prob|rate|thresh|residual)(s?)($|_)",
    re.IGNORECASE)


def _stmt_of(ctx: ModuleCtx, node: ast.AST) -> ast.stmt | None:
    cur = node
    while cur is not None and not isinstance(cur, ast.stmt):
        cur = ctx.parents.get(cur)
    return cur


def _stmt_is_exempt(ctx: ModuleCtx, node: ast.AST) -> bool:
    stmt = _stmt_of(ctx, node)
    if stmt is None:
        return False
    for sub in ast.walk(stmt):
        if isinstance(sub, ast.Name) and EXEMPT_NAME_RE.search(sub.id):
            return True
        if isinstance(sub, ast.arg) and EXEMPT_NAME_RE.search(sub.arg):
            return True
    return False


class BitExactRule(Rule):
    code = "FTL004"
    name = "integer-datapath-bit-exactness"
    invariant = ("the protected datapath (quantize -> accumulate -> flip "
                 "-> truncate) is integer-only; floats appear only at the "
                 "scale/probability boundaries")

    def _datapath_functions(self, ctx: ModuleCtx):
        if DATAPATH_FILE_RE.search(ctx.path):
            yield from (n for n in ast.walk(ctx.tree)
                        if isinstance(n, FUNC_NODES))
            return
        for suffix, names in DATAPATH_FUNCS_BY_FILE.items():
            if ctx.path.endswith(suffix):
                for n in ast.walk(ctx.tree):
                    if (isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                            and n.name in names):
                        yield n

    def check(self, ctx: ModuleCtx):
        findings = []
        seen: set[int] = set()
        for func in self._datapath_functions(ctx):
            fname = getattr(func, "name", "<lambda>")
            if EXEMPT_NAME_RE.search(fname):
                continue              # e.g. residual_ber: probability math
            for node in ast.walk(func):
                if id(node) in seen:
                    continue
                seen.add(id(node))
                msg = self._classify(ctx, node)
                if msg:
                    findings.append(self.finding(ctx, node, msg))
        return findings

    def _classify(self, ctx: ModuleCtx, node: ast.AST) -> str | None:
        if isinstance(node, ast.Call):
            target = ctx.call_target(node)
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "astype" and node.args):
                dt = ctx.dotted(node.args[0])
                if dt in FLOAT_DTYPES and not _stmt_is_exempt(ctx, node):
                    return (f"float cast ({dt}) in the integer fault "
                            f"datapath — bit-exactness across "
                            f"backends/refs requires integer words until "
                            f"the final scale")
            elif target in FLOAT_PRODUCERS and not _stmt_is_exempt(ctx, node):
                return (f"float-producing op '{target}' in the integer "
                        f"fault datapath")
            elif target in INT_MATMULS:
                kwargs = {kw.arg for kw in node.keywords}
                if "preferred_element_type" not in kwargs:
                    return (f"'{target}' without preferred_element_type="
                            f"jnp.int32: accumulator dtype becomes "
                            f"backend-dependent, breaking kernel/ref "
                            f"bit-exactness")
        elif (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Div)
                and not _stmt_is_exempt(ctx, node)):
            return ("true division in the integer fault datapath produces "
                    "floats — use shifts/floordiv (the DLA truncates, it "
                    "does not divide)")
        return None


RULE = BitExactRule()
