"""ftlint rule registry.

Each rule module exposes a ``RULE`` instance; the order here is the report
order.  Rule catalogue and motivating bugs: docs/ftlint.md.
"""
from __future__ import annotations

import ast

from tools.ftlint.core import Finding
from tools.ftlint.jaxctx import ModuleCtx


class Rule:
    """Base class: subclasses set ``code``/``name``/``invariant`` and
    implement ``check``."""

    code = "FTL000"
    name = "abstract"
    invariant = ""

    def check(self, ctx: ModuleCtx) -> list[Finding]:
        raise NotImplementedError

    def finding(self, ctx: ModuleCtx, node: ast.AST, message: str) -> Finding:
        return Finding(self.code, ctx.path, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), ctx.scope_of(node),
                       message)


from tools.ftlint.rules.ftl001_key_reuse import RULE as FTL001  # noqa: E402
from tools.ftlint.rules.ftl002_nondeterminism import RULE as FTL002  # noqa: E402
from tools.ftlint.rules.ftl003_policy_pytree import RULE as FTL003  # noqa: E402
from tools.ftlint.rules.ftl004_bit_exact import RULE as FTL004  # noqa: E402
from tools.ftlint.rules.ftl005_pallas import RULE as FTL005  # noqa: E402
from tools.ftlint.rules.ftl006_jit_cache import RULE as FTL006  # noqa: E402
from tools.ftlint.rules.ftl007_config_update import RULE as FTL007  # noqa: E402

ALL_RULES = (FTL001, FTL002, FTL003, FTL004, FTL005, FTL006, FTL007)
