"""FTL002 — nondeterminism inside traced code.

Invariant: everything under a JAX trace (``@jit`` bodies, ``lax.scan`` /
``while_loop`` / ``cond`` bodies, Pallas kernels) must be a pure function
of its traced inputs.  Host-side randomness (stdlib ``random``,
``np.random``), wall-clock reads (``time.*``, ``datetime.now``), host
syncs (``.item()``), and hash-order iteration over sets bake an arbitrary
trace-time value into the compiled executable — the fault-injection
protocol's determinism (same key, same faults, bit-exact replays) breaks
without any test necessarily noticing.

The serving parity suite (tests/test_serve_engine.py) only proves
determinism for the paths it runs; this rule proves the absence of the
nondeterminism *sources* everywhere.
"""
from __future__ import annotations

import ast

from tools.ftlint.jaxctx import ModuleCtx
from tools.ftlint.rules import Rule

# canonical dotted prefixes that are nondeterministic or host-syncing
BANNED_PREFIXES = (
    "random.",          # stdlib Mersenne Twister
    "time.",            # wall clock
    "numpy.random.",
    "np.random.",
    "secrets.",
    "uuid.",
)
BANNED_EXACT = {
    "datetime.datetime.now", "datetime.datetime.utcnow", "datetime.now",
    "os.urandom",
}


def _is_set_expr(node: ast.AST, ctx: ModuleCtx) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        return ctx.call_target(node) in ("set", "frozenset")
    return False


class NondeterminismRule(Rule):
    code = "FTL002"
    name = "nondeterminism-in-traced-code"
    invariant = ("traced code is a pure function of its inputs: no host "
                 "randomness, wall-clock, host syncs, or set-order "
                 "iteration at trace time")

    def check(self, ctx: ModuleCtx):
        findings = []
        for node in ast.walk(ctx.tree):
            if not ctx.in_traced_code(node):
                continue
            if isinstance(node, ast.Call):
                target = ctx.call_target(node)
                if target and (target in BANNED_EXACT or any(
                        target.startswith(p) for p in BANNED_PREFIXES)):
                    findings.append(self.finding(
                        ctx, node,
                        f"call to '{target}' inside traced code bakes a "
                        f"host-side/nondeterministic value into the "
                        f"compiled executable"))
                elif (isinstance(node.func, ast.Attribute)
                      and node.func.attr == "item" and not node.args):
                    findings.append(self.finding(
                        ctx, node,
                        ".item() inside traced code forces a host sync "
                        "(and fails under jit on abstract values)"))
            elif isinstance(node, (ast.For, ast.comprehension)):
                it = node.iter
                if _is_set_expr(it, ctx):
                    findings.append(self.finding(
                        ctx, it,
                        "iteration over a set inside traced code: set order "
                        "depends on PYTHONHASHSEED, so the traced program "
                        "differs across processes — sort or use a "
                        "tuple/list"))
        return findings


RULE = NondeterminismRule()
