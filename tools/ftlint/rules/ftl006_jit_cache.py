"""FTL006 — jit cache-key hazards.

Invariant: every ``jax.jit`` cache key is cheap, hashable, and stable.
The repo's serving/DSE throughput story rests on executables being
compiled once and hit forever (the scan-fused decode loop, the
treedef-keyed oracle cache); three patterns silently break that:

  * **unhashable or array-valued static args** — a list/dict/set default
    or an array annotation on a static-marked parameter either raises at
    call time or, worse, retraces per call;
  * **policies marked static** — a ``ProtectionPolicy`` is a pytree whose
    treedef *is* the intended cache key; passing one via
    ``static_argnums/names`` keys the cache on object hash instead, so
    structurally-identical policies rebuild executables (treedefs
    rebuilt per call, the PR 2 oracle-cache bug class);
  * **jit created per iteration / per bound method** — ``jax.jit(...)``
    inside a loop body, or on a bound-method attribute, creates a fresh
    callable each time and retraces on every use.
"""
from __future__ import annotations

import ast
import re

from tools.ftlint.jaxctx import ModuleCtx
from tools.ftlint.rules import Rule

POLICY_PARAM_RE = re.compile(r"(^|_)(policy|pol|policies)($|_)",
                             re.IGNORECASE)
ARRAY_ANNOT_RE = re.compile(r"\b(jax\.Array|jnp\.ndarray|np\.ndarray|"
                            r"numpy\.ndarray|Array)\b")
UNHASHABLE_NODES = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
                    ast.SetComp)


def _static_params(ctx: ModuleCtx, jit_call: ast.Call,
                   func: ast.FunctionDef) -> list[ast.arg]:
    """Parameters of ``func`` marked static in a jit call/decorator."""
    args = func.args
    pos = args.posonlyargs + args.args
    byname = {a.arg: a for a in pos + args.kwonlyargs}
    out: list[ast.arg] = []
    for kw in jit_call.keywords:
        if kw.arg == "static_argnames":
            vals = (kw.value.elts if isinstance(kw.value,
                                                (ast.Tuple, ast.List))
                    else [kw.value])
            for v in vals:
                if isinstance(v, ast.Constant) and v.value in byname:
                    out.append(byname[v.value])
        elif kw.arg == "static_argnums":
            vals = (kw.value.elts if isinstance(kw.value,
                                                (ast.Tuple, ast.List))
                    else [kw.value])
            for v in vals:
                if (isinstance(v, ast.Constant)
                        and isinstance(v.value, int)
                        and v.value < len(pos)):
                    out.append(pos[v.value])
    return out


class JitCacheRule(Rule):
    code = "FTL006"
    name = "jit-cache-key-hazards"
    invariant = ("jit cache keys are hashable, stable and policy-free: "
                 "policies ride as pytrees (treedef = cache key), jit "
                 "wrappers are created once")

    def check(self, ctx: ModuleCtx):
        findings = []
        defs: dict[str, ast.FunctionDef] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.FunctionDef):
                defs[node.name] = node

        for node in ast.walk(ctx.tree):
            # ---- decorator form: @partial(jax.jit, static_...) ----------
            if isinstance(node, ast.FunctionDef):
                for dec in node.decorator_list:
                    if isinstance(dec, ast.Call) and self._wraps_jit(ctx, dec):
                        findings.extend(
                            self._check_static(ctx, dec, node))
                continue
            if not isinstance(node, ast.Call):
                continue
            if ctx.call_target(node) != "jax.jit":
                continue

            # ---- call form: jax.jit(fn, static_...) ----------------------
            if node.args:
                wrapped = node.args[0]
                if isinstance(wrapped, ast.Name) and wrapped.id in defs:
                    findings.extend(self._check_static(
                        ctx, node, defs[wrapped.id]))
                elif isinstance(wrapped, ast.Attribute):
                    root = wrapped.value
                    root_name = (root.id if isinstance(root, ast.Name)
                                 else None)
                    if root_name is None or root_name not in ctx.aliases:
                        findings.append(self.finding(
                            ctx, node,
                            f"jax.jit on attribute "
                            f"'{ast.unparse(wrapped)}': a bound method is "
                            f"a fresh function object per access, so the "
                            f"jit cache never hits — jit a module-level "
                            f"function or wrap once in __init__"))

            # ---- jit-per-iteration ---------------------------------------
            if self._in_loop(ctx, node):
                findings.append(self.finding(
                    ctx, node,
                    "jax.jit(...) inside a loop body creates a new jitted "
                    "callable (and trace) per iteration — hoist the "
                    "wrapper out of the loop"))
        return findings

    # ------------------------------------------------------------ helpers --
    def _wraps_jit(self, ctx: ModuleCtx, call: ast.Call) -> bool:
        target = ctx.call_target(call)
        if target == "jax.jit":
            return True
        return (target in ("functools.partial", "partial") and call.args
                and ctx.dotted(call.args[0]) == "jax.jit")

    def _in_loop(self, ctx: ModuleCtx, node: ast.AST) -> bool:
        cur = ctx.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.For, ast.While, ast.AsyncFor)):
                return True
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                return False
            cur = ctx.parents.get(cur)
        return False

    def _check_static(self, ctx: ModuleCtx, jit_call: ast.Call,
                      func: ast.FunctionDef):
        findings = []
        params = _static_params(ctx, jit_call, func)
        args = func.args
        pos = args.posonlyargs + args.args
        defaults = dict(zip([a.arg for a in pos[len(pos)
                                                - len(args.defaults):]],
                            args.defaults))
        defaults.update({a.arg: d for a, d in zip(args.kwonlyargs,
                                                  args.kw_defaults) if d})
        for p in params:
            if POLICY_PARAM_RE.search(p.arg):
                findings.append(self.finding(
                    ctx, p,
                    f"static arg '{p.arg}' in jitted '{func.name}' looks "
                    f"like a protection policy: policies are pytrees — "
                    f"pass them dynamically so the treedef (not object "
                    f"hash) keys the executable cache"))
            d = defaults.get(p.arg)
            if d is not None and isinstance(d, UNHASHABLE_NODES):
                findings.append(self.finding(
                    ctx, p,
                    f"static arg '{p.arg}' in jitted '{func.name}' has an "
                    f"unhashable default ({type(d).__name__.lower()}): "
                    f"jit static args must be hashable — use a tuple/"
                    f"frozenset or make it dynamic"))
            ann = p.annotation
            if ann is not None and ARRAY_ANNOT_RE.search(
                    ast.unparse(ann)):
                findings.append(self.finding(
                    ctx, p,
                    f"static arg '{p.arg}' in jitted '{func.name}' is "
                    f"annotated as an array: array-valued static args "
                    f"retrace per value (or fail to hash) — pass arrays "
                    f"dynamically"))
        return findings


RULE = JitCacheRule()
