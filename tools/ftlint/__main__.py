"""Entry point: ``python -m tools.ftlint src tests benchmarks examples``."""
from tools.ftlint.core import main

raise SystemExit(main())
