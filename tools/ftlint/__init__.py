"""ftlint — repo-native static analysis for the fault-tolerance contracts.

The paper's reliability numbers are only as credible as the software's
invariants: exact fault-stream accounting (every fault draw keyed by a
fresh PRNG key), an integer-only protected datapath, policy pytrees whose
sole dynamic leaf is ``ber``, deterministic traced code, and Pallas kernels
that stay bit-exact against their references.  Those contracts used to live
in prose and in whichever parity tests someone remembered to write; ftlint
enforces them mechanically on every commit.

Usage:

    python -m tools.ftlint src tests benchmarks examples

See ``docs/ftlint.md`` for the rule catalogue and the bug each rule
generalizes.
"""
from tools.ftlint.core import Finding, lint_file, lint_paths, lint_source
from tools.ftlint.rules import ALL_RULES

__all__ = ["ALL_RULES", "Finding", "lint_file", "lint_paths", "lint_source"]
