"""Default target manifest: the repo's real executables, traced.

Each target is the jaxpr (and, where donation matters, the lowered HLO) of
an executable the test batteries actually run: the serving engine's fused
decode loop, the continuous-batching scheduler's prefill and paged decode
chunk, the fused_decode protect triplet, the FAT train step, and the
batched DSE oracle.  Everything is traced abstractly (``jax.make_jaxpr`` /
``jax.eval_shape`` / ``jit(...).lower``) — nothing executes, so the whole
manifest runs in single-device CI; mesh targets trace under whatever mesh
the host devices allow (sharding_constraint eqns survive even a 1x1 mesh).

Trace shapes are deliberately tiny: every rule here is structural (dataflow,
dtypes, eqn params), so reduced configs exercise exactly the same contracts
as the full models.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from tools.ftverify.core import Target

_sds = jax.ShapeDtypeStruct


def _key_aval(batch=None):
    """Raw uint32 key aval(s) matching ``jax.random.PRNGKey``."""
    return _sds(((batch, 2) if batch else (2,)), jnp.uint32)


def _mesh():
    devs = jax.devices()
    tp = 2 if len(devs) % 2 == 0 and len(devs) >= 2 else 1
    dp = len(devs) // tp
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs).reshape(dp, tp), ("data", "model"))


@functools.lru_cache(maxsize=1)
def _danube():
    from repro.configs import get_config
    from repro.models import build
    cfg = get_config("h2o-danube-1.8b", reduced=True)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _policy():
    from repro.ft import get_policy
    # weight_faults=False bounds trace cost on the full-model targets (the
    # weight planes double every site's injection graph); the protect
    # triplet below keeps the default weight_faults=True
    return get_policy("crt3", ber=1e-3, weight_faults=False)


# ------------------------------------------------------- protect triplet --
def _protect_targets() -> list[Target]:
    from repro.ft import get_policy, protect_linear
    from repro.kernels.fused_decode.ops import fused_protect_linear

    pol = get_policy("crt3", ber=1e-3)
    x, w = _sds((4, 8), jnp.float32), _sds((8, 8), jnp.float32)
    tags = frozenset({"protect", "rng"})

    def ref():
        return jax.make_jaxpr(
            lambda k, xx, ww: protect_linear(k, xx, ww, pol))(
                _key_aval(), x, w)

    def fused():
        return jax.make_jaxpr(
            lambda k, xx, ww: fused_protect_linear(k, xx, ww, pol,
                                                   interpret=True))(
                _key_aval(), x, w)

    def perrow():
        return jax.make_jaxpr(
            lambda k, xx, ww: protect_linear(k, xx, ww, pol))(
                _key_aval(batch=4), x, w)

    return [Target("protect.reference", tags, trace=ref),
            Target("protect.fused", tags, trace=fused),
            Target("protect.perrow", tags, trace=perrow)]


# ---------------------------------------------------------------- engine --
def _engine(mesh=None):
    from repro.serve.engine import Engine, ServeConfig
    _, m, params = _danube()
    return Engine(m, params, mesh=mesh, cfg=ServeConfig(max_new_tokens=4),
                  policy=_policy())


def _engine_avals(eng, n_new: int = 4):
    cfg, _, params = _danube()
    batch = {"tokens": _sds((2, 9), jnp.int32)}
    max_len = 9 + n_new
    caches, logits = jax.eval_shape(
        lambda p, b, k: eng._prefill(p, b, max_len, k),
        params, batch, _key_aval())
    tok = _sds(logits.shape[:-1], jnp.int32)
    pos0 = _sds((), jnp.int32)
    return params, caches, tok, pos0, batch, max_len


def _engine_targets() -> list[Target]:
    out = []
    for label, mesh in (("", None), (".mesh", _mesh())):
        eng = _engine(mesh)
        n_new = 4
        params, caches, tok, pos0, batch, max_len = _engine_avals(eng, n_new)
        tags = frozenset({"serve", "rng", "protect"}
                         | ({"mesh"} if mesh is not None else set()))
        loop_args = (params, caches, tok, pos0, _key_aval(), _key_aval())

        def trace(eng=eng, a=loop_args, n=n_new):
            return jax.make_jaxpr(
                lambda p, c, t, q, fk, sk: eng._loop(p, c, t, q, fk, sk, n)
            )(*a)

        def lower(eng=eng, a=loop_args, n=n_new):
            return eng._loop.lower(*a, n).as_text()

        out.append(Target(
            f"engine.decode_loop{label}", tags, trace=trace, lower=lower,
            donated_leaves=len(jax.tree_util.tree_leaves(caches)),
            mesh=mesh))
        if mesh is not None:
            def trace_pf(eng=eng, p=params, b=batch, ml=max_len):
                return jax.make_jaxpr(
                    lambda pp, bb, k: eng._prefill(pp, bb, ml, k)
                )(p, b, _key_aval())

            out.append(Target("engine.prefill.mesh", tags, trace=trace_pf,
                              mesh=mesh))
    return out


# ------------------------------------------------------------- scheduler --
def _sched_targets() -> list[Target]:
    from repro.serve.scheduler import Scheduler, SchedulerConfig
    _, m, params = _danube()
    sched = Scheduler(m, params, SchedulerConfig(
        max_batch=2, buckets=(8,), max_new_tokens=8, decode_chunk=2,
        kv="paged", block_size=8), policy=_policy())
    tags = frozenset({"serve", "rng", "protect"})

    def trace_prefill():
        return jax.make_jaxpr(sched._prefill_one)(
            params, {"tokens": _sds((1, 8), jnp.int32)},
            _sds((1,), jnp.int32), _sds((), jnp.int32))

    caches = jax.eval_shape(lambda: sched._init_caches(2))
    B = 2
    chunk_args = (params, caches, _sds((B,), jnp.int32),
                  _sds((B,), jnp.int32), _sds((B,), jnp.int32),
                  _sds((B,), jnp.int32), _sds((B,), jnp.bool_))

    def trace_chunk():
        return jax.make_jaxpr(
            lambda p, c, t, q, s, r, a: sched._chunk(p, c, t, q, s, r, a, 2)
        )(*chunk_args)

    def lower_chunk():
        return sched._chunk.lower(*chunk_args, 2).as_text()

    return [
        Target("scheduler.prefill", tags, trace=trace_prefill),
        Target("scheduler.chunk.paged", tags, trace=trace_chunk,
               lower=lower_chunk,
               donated_leaves=len(jax.tree_util.tree_leaves(caches))),
    ]


# ------------------------------------------------------------ train step --
def _train_target() -> list[Target]:
    from repro.optim import AdamWConfig
    from repro.train.train_step import init_state, make_train_step
    _, m, _ = _danube()
    opt = AdamWConfig(lr=1e-3)
    step, jit_step = make_train_step(m, opt, policy=_policy(), fat_ramp=10)
    state = jax.eval_shape(lambda k: init_state(m, k, opt),
                           jax.random.PRNGKey(0))
    batch = {"tokens": _sds((2, 16), jnp.int32)}
    tags = frozenset({"train", "rng", "protect"})

    def trace():
        return jax.make_jaxpr(step)(state, batch)

    def lower():
        return jit_step.lower(state, batch).as_text()

    return [Target("train.fat_step", tags, trace=trace, lower=lower,
                   donated_leaves=len(jax.tree_util.tree_leaves(state)))]


# ------------------------------------------------------------ DSE oracle --
def _dse_target() -> list[Target]:
    from repro.core.evaluate import _acc_under_fault
    from repro.ft import get_policy
    from repro.models.cnn import CNNConfig, init_cnn

    cfg = CNNConfig()
    params = jax.eval_shape(lambda k: init_cnn(k, cfg), _key_aval())
    pol = get_policy("crt3", ber=1e-3)
    _, treedef = jax.tree_util.tree_flatten(pol)
    R = 2
    args = (params, _sds((4, cfg.hw, cfg.hw, cfg.in_channels), jnp.float32),
            _sds((4,), jnp.int32), _sds((R,), jnp.float32), _key_aval(R))

    def trace():
        return jax.make_jaxpr(
            lambda p, i, l, b, k: _acc_under_fault(
                p, cfg, i, l, b, k, {}, treedef=treedef, protected=None)
        )(*args)

    return [Target("dse.batched_oracle",
                   frozenset({"protect", "rng", "dse"}), trace=trace)]


def default_manifest() -> list[Target]:
    return (_protect_targets() + _engine_targets() + _sched_targets()
            + _train_target() + _dse_target())
