"""FTV104 — "BER is the only dynamic leaf", machine-checked.

The DSE oracle and the FAT BER ramp both rely on one property of
``ProtectionPolicy``: the BER is the single pytree leaf, everything else is
static treedef structure.  That is what lets a BER ramp run as ONE
executable (the traced step counter rides the leaf through
``fat_ber_at`` -> ``with_ber``) and lets the batched oracle put whole
candidate sweeps on a vmap axis keyed only on the canonical treedef.

These are registry-wide properties, so this rule runs globally (no target):

* every registered policy flattens to exactly one leaf;
* ``with_ber`` preserves the treedef (the jit-cache key of the oracle
  executables — a structure change would silently recompile per BER point);
* ``tree_unflatten`` + the full protected datapath trace with an *abstract*
  BER derived from an abstract step counter (``fat_ber_at``).  If any code
  on the path concretizes the BER (a python ``if ber == 0:``, a
  ``float(ber)``), the trace raises and the sweep shatters into one
  executable per operating point;
* tuning the numeric Table-I knobs (``ib_th`` / ``nb_th`` / ``s_th``) must
  not change the ``_batch_canon`` canonical structure — those knobs ride
  the batch axis in ``_acc_under_fault_dyn``, so moving one onto the
  treedef would break cross-candidate batching.
"""
from __future__ import annotations

from tools.ftlint.core import Finding
from tools.ftverify.rules import TraceRule


def _gfind(code: str, scope: str, msg: str) -> Finding:
    return Finding(code, "global://ft.registry", 0, 0, scope, msg)


def check_policy_leaves(finding) -> list:
    import jax
    from repro.ft import get_policy, list_policies
    out = []
    for name in list_policies():
        pol = get_policy(name)
        leaves, treedef = jax.tree_util.tree_flatten(pol)
        if len(leaves) != 1:
            out.append(finding(
                name,
                f"policy {name!r} flattens to {len(leaves)} leaves — BER "
                f"must be the only dynamic leaf or every sweep recompiles "
                f"per point"))
            continue
        td2 = jax.tree_util.tree_structure(pol.with_ber(0.123))
        if td2 != treedef:
            out.append(finding(
                name,
                f"policy {name!r}: with_ber() changes the treedef — the "
                f"oracle jit cache keys on the treedef, so every BER point "
                f"would compile its own executable"))
    return out


def check_abstract_ber_trace(finding) -> list:
    """Trace step -> fat_ber_at -> with_ber -> protect_linear with an
    abstract step counter: success == the whole BER ramp is one
    executable."""
    import jax
    import jax.numpy as jnp
    from repro.ft import get_policy, list_policies, protect_linear
    from repro.train.train_step import fat_ber_at

    key = jax.random.PRNGKey(0)
    x = jax.ShapeDtypeStruct((4, 8), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    out = []
    for name in list_policies():
        pol = get_policy(name)
        _, treedef = jax.tree_util.tree_flatten(pol)

        def ramp_step(s, xx, ww, _td=treedef):
            ber = fat_ber_at(1e-3, 100, s)
            p = jax.tree_util.tree_unflatten(_td, (ber,))
            return protect_linear(key, xx, ww, p)

        try:
            jax.eval_shape(ramp_step, step, x, w)
        except Exception as e:  # noqa: BLE001 — any trace error is the finding
            out.append(finding(
                name,
                f"policy {name!r} concretizes the BER under tracing "
                f"({type(e).__name__}: {str(e).splitlines()[0][:140]}) — "
                f"the BER ramp / registry sweep cannot run as one "
                f"executable"))
    return out


def check_batch_canon(finding) -> list:
    import jax
    out = []
    try:
        from repro.core.evaluate import _batch_canon
    except Exception as e:  # noqa: BLE001
        return [finding("import", f"cannot import _batch_canon: {e}")]
    from repro.ft import get_policy, list_policies
    for name in list_policies():
        pol = get_policy(name)
        base = jax.tree_util.tree_structure(_batch_canon(pol))
        for knob, val in (("ib_th", 5), ("nb_th", 2), ("s_th", 0.25)):
            try:
                tuned = pol.tune(**{knob: val})
            except TypeError:
                continue
            if jax.tree_util.tree_structure(_batch_canon(tuned)) != base:
                out.append(finding(
                    name,
                    f"policy {name!r}: tuning {knob} changes the canonical "
                    f"batching structure (_batch_canon) — that knob is "
                    f"supposed to ride the vmap axis, not the treedef; "
                    f"candidates differing only in {knob} would stop "
                    f"sharing one executable"))
    return out


class OneExecutableRule(TraceRule):
    code = "FTV104"
    name = "one-executable-sweeps"
    invariant = ("BER is the only policy pytree leaf; with_ber preserves "
                 "the treedef; the protected datapath traces with an "
                 "abstract BER; numeric knobs don't perturb _batch_canon")
    tags = frozenset()

    def check_global(self, env):
        def finding(scope, msg):
            return _gfind(self.code, scope, msg)
        return (check_policy_leaves(finding)
                + check_abstract_ber_trace(finding)
                + check_batch_canon(finding))


RULE = OneExecutableRule()
