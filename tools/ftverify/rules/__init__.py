"""ftverify rule registry.

Each rule module exposes a ``RULE`` instance.  A rule can implement two
hooks: ``check_target(ctx)`` runs per manifest target (``ctx`` is a
:class:`tools.ftverify.core.TargetCtx` with the lazy jaxpr graph and
lowered HLO), and ``check_global(env)`` runs once per verification pass
(for process-wide facts like config flags and the policy-sweep traces).
``applies(target)`` gates ``check_target`` on the target's tags.

Rule catalogue with the motivating PR 9 bugs: docs/ftlint.md §ftverify.
"""
from __future__ import annotations


class TraceRule:
    code = "FTV000"
    name = "abstract"
    invariant = ""
    tags: frozenset = frozenset()        # run on targets carrying any of these

    def applies(self, target) -> bool:
        return not self.tags or bool(self.tags & target.tags)

    def check_target(self, ctx):
        return []

    def check_global(self, env):
        return []


from tools.ftverify.rules.ftv101_int_datapath import RULE as FTV101  # noqa: E402
from tools.ftverify.rules.ftv102_partition import RULE as FTV102  # noqa: E402
from tools.ftverify.rules.ftv103_key_streams import RULE as FTV103  # noqa: E402
from tools.ftverify.rules.ftv104_one_executable import RULE as FTV104  # noqa: E402
from tools.ftverify.rules.ftv105_donation import RULE as FTV105  # noqa: E402
from tools.ftverify.rules.ftv106_sharding import RULE as FTV106  # noqa: E402

ALL_RULES = (FTV101, FTV102, FTV103, FTV104, FTV105, FTV106)
