"""FTV101 — integer-datapath purity, checked on the IR.

Invariant: everything feeding a truncation shift (the ``(acc+half) >> t``
of ``truncate_acc``) is integer arithmetic back to the quantization
boundary (``round``), the randomness boundary (``random_*``), or a boolean
predicate; and no value derived from an injected word takes a float
excursion that re-enters the integer path without re-quantizing.

FTL004 enforces this contract on the AST, but only inside the named
datapath files — a float cast hidden behind a helper in another module
(or introduced by an optimization "simplifying" ``truncate_acc``) is
invisible there.  Here the check runs on the flattened jaxpr, so helper
indirection doesn't exist: if a float op's output reaches the shift, it
is flagged no matter which module traced it.

Also checked: every ``dot_general`` on the slice accumulates in >= 32
integer bits (an int8xint8->int8 dot silently overflows the 24-bit
accumulator contract), and injected (xor) words never round-trip through
floats without a ``round`` (a raw ``astype(int32)`` after float math is
truncation toward zero — bit-inexact by construction).
"""
from __future__ import annotations

import jax.numpy as jnp

from tools.ftverify.rules import TraceRule

# float ops sanctioned on the backward walk: the clip half of the quantize
# pattern (round -> clip -> convert) plus value-preserving layout ops
QUANT_OK = frozenset({
    "clip", "max", "min", "convert_element_type", "select_n",
    "broadcast_in_dim", "reshape", "squeeze", "transpose", "slice",
    "concatenate", "expand_dims", "rev", "copy", "stop_gradient",
})
CALL_PRIMS = frozenset({
    "pjit", "closed_call", "core_call", "remat2", "checkpoint",
    "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
    "scan", "while", "cond", "pallas_call",
})
STOP_PRIMS = frozenset({"round", "iota"})

# ops that forward values unchanged for the float-roundtrip forward walk
FWD_PASS = frozenset({
    "reshape", "squeeze", "transpose", "slice", "broadcast_in_dim",
    "concatenate", "expand_dims", "select_n", "copy", "stop_gradient",
    "add", "sub", "mul", "max", "min", "neg",
})


def check_backward_slices(g, finding) -> list:
    """Walk backward from every truncation shift; flag float arithmetic and
    narrow integer dots on the way to the quantize/random/bool boundaries."""
    out = []
    for sra in g.eqns_by_prim("shift_right_arithmetic"):
        if not g.is_int(sra.outvars[0]):
            continue
        seen: set[int] = set()
        work = list(sra.invars)
        flagged: set[int] = set()
        while work:
            v = g.find(work.pop())
            if v in seen or g.is_literal(v) or v in g.const_ids:
                continue
            seen.add(v)
            if g.is_bool(v):
                continue                    # predicates are sanctioned
            pr = g.producer(v)
            if pr is None:
                continue
            pe, _ = pr
            if pe.prim in STOP_PRIMS or pe.prim.startswith("random"):
                continue                    # quantize / randomness boundary
            if pe.prim == "dot_general" and pe.idx not in flagged:
                dt = g.dtype(pe.outvars[0])
                if dt is not None and jnp.issubdtype(dt, jnp.integer) \
                        and jnp.iinfo(dt).bits < 32:
                    flagged.add(pe.idx)
                    out.append(finding(
                        "truncation",
                        f"dot_general accumulates in {dt} (<32 bits) on "
                        f"the path into a truncation shift — pin "
                        f"preferred_element_type=jnp.int32 (24-bit "
                        f"accumulator contract)"))
            if g.is_float(v) and pe.prim not in QUANT_OK \
                    and pe.prim not in CALL_PRIMS:
                if pe.idx not in flagged:
                    flagged.add(pe.idx)
                    out.append(finding(
                        "truncation",
                        f"float '{pe.prim}' feeds the integer datapath "
                        f"into a truncation shift (path {'/'.join(pe.path) or '<top>'}) "
                        f"— the protected slice must be integer-exact "
                        f"back to the round() quantize boundary"))
                continue                    # report the entry, don't recurse
            work.extend(pe.invars)
    return out


def check_injected_roundtrips(g, finding) -> list:
    """Forward from every xor (fault application): an int->float convert
    whose value re-enters an integer dtype without passing ``round`` is a
    float round-trip on injected words — flag it."""
    out = []
    flagged: set[int] = set()
    seen: set[int] = set()
    work = [v for x in g.eqns_by_prim("xor") if g.is_int(x.outvars[0])
            for v in x.outvars]
    while work:
        v = g.find(work.pop())
        if v in seen:
            continue
        seen.add(v)
        for ce, _ in g.consumers(v):
            if ce.prim == "convert_element_type" and g.is_int(v) \
                    and g.is_float(ce.outvars[0]):
                # entering a float excursion: scan forward for a float->int
                # reconvert with no round() in between
                if ce.idx not in flagged \
                        and _reenters_int_without_round(g, ce.outvars[0]):
                    flagged.add(ce.idx)
                    out.append(finding(
                        "injection",
                        "injected (xor) words take a float round-trip "
                        "that re-enters int without a round() — raw "
                        "float->int casts truncate toward zero and break "
                        "bit-exactness"))
            elif ce.prim in FWD_PASS or ce.prim in CALL_PRIMS \
                    or ce.prim in ("and", "or", "xor",
                                   "shift_right_arithmetic",
                                   "shift_left", "dot_general",
                                   "convert_element_type"):
                for ov in ce.outvars:
                    if g.is_int(ov):
                        work.append(ov)
    return out


def _reenters_int_without_round(g, start, depth: int = 8) -> bool:
    seen: set[int] = set()
    work = [(start, 0)]
    while work:
        v, d = work.pop()
        v = g.find(v)
        if v in seen or d > depth:
            continue
        seen.add(v)
        for ce, _ in g.consumers(v):
            if ce.prim == "round":
                continue                     # re-quantization: sanctioned
            if ce.prim == "convert_element_type" \
                    and g.is_int(ce.outvars[0]):
                return True
            for ov in ce.outvars:
                if not g.is_float(ov):
                    continue
                # ce may be a call eqn wrapping the round (jnp.round is a
                # pjit); the producer map prefers inner eqns, so a rounded
                # output identifies itself here
                pr = g.producer(ov)
                if pr is not None and pr[0].prim == "round":
                    continue
                work.append((ov, d + 1))
    return False


class IntDatapathRule(TraceRule):
    code = "FTV101"
    name = "integer-datapath-purity"
    invariant = ("the jaxpr slice between fault injection (xor) and "
                 "truncation (shift_right_arithmetic) is integer-exact: no "
                 "float arithmetic, no sub-32-bit accumulation, no raw "
                 "float->int casts on injected words")
    tags = frozenset({"protect"})

    def check_target(self, ctx):
        g = ctx.graph
        if g is None:
            return []

        def finding(scope, msg):
            return ctx.finding(self.code, scope, msg)

        return (check_backward_slices(g, finding)
                + check_injected_roundtrips(g, finding))


RULE = IntDatapathRule()
