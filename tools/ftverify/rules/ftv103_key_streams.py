"""FTV103 — key-stream discipline on the traced draws.

The repo's contract (``repro.core.faults.fold_stream``): every consumer of
fault randomness addresses its draws by a distinct fold_in path under one
root key.  ftlint's FTL003 checks the *call sites*; this rule checks the
*draws*: in the flattened jaxpr, every ``random_bits`` key operand must have
a distinct origin — two draws whose keys resolve (through splits, fold_ins,
reshapes, slices) to the same producer consume the same stream, no matter
how the key was laundered through helpers on the way.

Also checked: a ``random_bits`` inside a ``scan`` body must derive its key
from the loop state (the carry or the scanned-over xs).  A key closed over
from outside the scan replays the identical fault pattern every iteration —
the serving-loop bug class the engine avoids by folding the step index
``i + 1`` into the fault key *inside* the scan.
"""
from __future__ import annotations

from tools.ftverify.rules import TraceRule


def check_reuse(g, finding) -> list:
    """Group random_bits draws by the canonical origin of their key."""
    groups: dict = {}
    for e in g.eqns_by_prim("random_bits"):
        if not e.invars:
            continue
        groups.setdefault(g.origin_sig(e.invars[0]), []).append(e)
    out = []
    for sig, eqns in groups.items():
        if len(eqns) < 2:
            continue
        # the same key may be drawn in mutually-exclusive cond branches
        if all("cond" in e.path for e in eqns):
            continue
        where = ", ".join(
            f"eqn{e.idx}@{'/'.join(e.path) or '<top>'}" for e in eqns[:4])
        out.append(finding(
            "key-reuse",
            f"{len(eqns)} random_bits draws share one key origin ({where}"
            f"{', ...' if len(eqns) > 4 else ''}) — two sites consume the "
            f"same fault stream; derive each from a distinct fold_in path "
            f"(repro.core.faults.fold_stream)"))
    return out


def check_scan_invariance(g, finding) -> list:
    """A draw inside a scan whose key does not depend on the carry/xs
    replays the same bits every iteration."""
    out = []
    flagged: set[int] = set()
    for e in g.eqns_by_prim("random_bits"):
        if not e.scans or not e.invars:
            continue
        scan_idx = e.scans[-1]
        variant = g.scan_variant_roots(scan_idx)
        if g.find(e.invars[0]) not in variant and e.idx not in flagged:
            flagged.add(e.idx)
            out.append(finding(
                "scan-invariant-key",
                f"random_bits (eqn{e.idx}@{'/'.join(e.path)}) inside a scan "
                f"draws from a key independent of the carry and xs — the "
                f"same fault pattern is replayed every loop iteration; "
                f"fold the step index into the key inside the scan body"))
    return out


class KeyStreamRule(TraceRule):
    code = "FTV103"
    name = "key-stream-discipline"
    invariant = ("every random_bits key has a distinct fold_in origin, and "
                 "draws inside scan bodies vary with the loop state")
    tags = frozenset({"rng", "protect"})

    def check_target(self, ctx):
        g = ctx.graph
        if g is None:
            return []

        def finding(scope, msg):
            return ctx.finding(self.code, scope, msg)

        return check_reuse(g, finding) + check_scan_invariance(g, finding)


RULE = KeyStreamRule()
