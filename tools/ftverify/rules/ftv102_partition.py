"""FTV102 — partition invariance of the randomness and the float boundary.

PR 9's sharded-serving battery found two ways a bit-exactness contract can
hold on one device and silently break on a mesh:

* **Legacy threefry lowering** computes ``random_bits`` with a
  partition-*variant* counter layout: the same key produces different words
  at TP=1 and TP=N.  The repo pins ``jax_threefry_partitionable=True`` once
  at ``repro.core.faults`` import; this rule checks the live config flag AND
  probes the actual lowering for the partitionable signature (a ``ui64``
  iota over the flat counter space) — a stale early trace or a stray
  ``jax.config.update`` elsewhere would pass the flag check but fail the
  lowering probe.

* **Excess-precision elision**: XLA may fuse an ``f32 -> bf16 -> f32``
  convert pair into a no-op, keeping full f32 precision *on some shards
  only* (fusion decisions are per-partition) — a cross-device value
  divergence on the quantization inputs.  Pinning
  ``--xla_allow_excess_precision=false`` in ``XLA_FLAGS`` forces the
  rounding everywhere.  This rule finds the vulnerable convert pairs in
  every traced target and flags them unless the flag is pinned; CI runs one
  arm without the pin (``--no-pin-excess-precision --expect FTV102``) to
  prove the rule actually fires on the real executables.
"""
from __future__ import annotations

from tools.ftlint.core import Finding
from tools.ftverify.rules import TraceRule

# the partitionable threefry lowering enumerates the counter space with a
# 64-bit iota; the legacy lowering builds 32-bit halves and slices
PARTITIONABLE_MARKER = "xui64>"


def _gfind(code: str, path: str, scope: str, msg: str) -> Finding:
    return Finding(code, path, 0, 0, scope, msg)


def probe_threefry_lowering() -> str:
    """StableHLO of a minimal random_bits executable (current process
    config)."""
    import jax
    import jax.numpy as jnp
    k = jax.random.key(0)
    return jax.jit(
        lambda key: jax.random.bits(key, (256,), jnp.uint32)
    ).lower(k).as_text()


def check_config(env, finding) -> list:
    out = []
    if not env.threefry_partitionable:
        out.append(finding(
            "jax.config",
            "jax_threefry_partitionable is False — legacy threefry lowering "
            "is partition-variant: the same key yields different random "
            "bits at TP=1 vs TP=N (repro.core.faults pins this flag at "
            "import; something ran before it or flipped it back)"))
        return out
    hlo = probe_threefry_lowering()
    if PARTITIONABLE_MARKER not in hlo:
        out.append(finding(
            "threefry-lowering",
            "jax_threefry_partitionable is set but random_bits lowers "
            "without the partitionable ui64 counter iota — the flag was "
            "flipped after a trace was cached, or the lowering path "
            "changed; random draws are not partition-invariant"))
    return out


def find_bf16_roundtrips(g) -> list:
    """(f32 -> bf16 convert, bf16 -> f32 convert) consumer pairs."""
    import jax.numpy as jnp
    pairs = []
    for e in g.eqns_by_prim("convert_element_type"):
        if not (g.dtype(e.invars[0]) == jnp.float32
                and g.dtype(e.outvars[0]) == jnp.bfloat16):
            continue
        for ce, _ in g.consumers(e.outvars[0]):
            if ce.prim == "convert_element_type" \
                    and g.dtype(ce.outvars[0]) == jnp.float32:
                pairs.append((e, ce))
    return pairs


class PartitionRule(TraceRule):
    code = "FTV102"
    name = "partition-invariance"
    invariant = ("threefry lowers in partitionable (ui64 counter) form, and "
                 "every f32->bf16->f32 convert pair in a traced executable "
                 "is protected from excess-precision elision by pinning "
                 "--xla_allow_excess_precision=false")
    tags = frozenset()                       # every traced target

    def check_global(self, env):
        return check_config(
            env, lambda scope, msg: _gfind(self.code, "global://threefry",
                                           scope, msg))

    def check_target(self, ctx):
        if ctx.env.excess_precision_pinned:
            return []
        g = ctx.graph
        if g is None:
            return []
        pairs = find_bf16_roundtrips(g)
        if not pairs:
            return []
        where = sorted({"/".join(e.path) or "<top>" for e, _ in pairs})
        return [ctx.finding(
            self.code, "excess-precision",
            f"{len(pairs)} f32->bf16->f32 convert pair(s) (in "
            f"{', '.join(where[:4])}{'...' if len(where) > 4 else ''}) with "
            f"--xla_allow_excess_precision=false NOT pinned in XLA_FLAGS — "
            f"XLA may elide the bf16 rounding on some shards only, "
            f"breaking cross-device bit-exactness of the quantization "
            f"inputs")]


RULE = PartitionRule()
