"""FTV106 — sharding-constraint coverage at the partition-sensitive spots.

Two constraints PR 9 added after chasing real cross-device divergences:

* **Post-rope re-constraint**: rope mixes the head dim in f32; without an
  activation constraint right after it, the residual stream's sequence
  sharding propagates into the kv length dim and the softmax ``p @ v``
  contraction becomes a partitioned float sum — a reordered accumulation
  that is not bitwise partition-invariant.  On the jaxpr this reads: every
  rope output (a 2-way ``concatenate`` of cos/sin-modulated halves) must
  reach a ``sharding_constraint`` before any ``dot_general`` or cache
  write.  Constraint eqns survive tracing even on a 1x1 mesh, so this
  check runs in single-device CI.

* **Paged-pool replication**: paged KV pools index by *global* block id, so
  ``cache_shardings`` must keep the pool and block dims replicated over the
  DP axes (sharding dim 0 as if it were batch breaks every block-table
  lookup) while still sharding kv heads over 'model'.  Checked directly
  against ``cache_shardings`` on a representative paged + dense layout.
"""
from __future__ import annotations

from tools.ftlint.core import Finding
from tools.ftverify.rules import TraceRule

# ops a rope output may legitimately flow through before its constraint
_ALLOWED = frozenset({
    "convert_element_type", "reshape", "broadcast_in_dim", "transpose",
    "squeeze", "expand_dims", "slice", "copy", "stop_gradient",
    "mul", "add", "sub", "concatenate",
})
_BAD = frozenset({"dot_general", "dynamic_update_slice", "scatter",
                  "scatter-add", "gather"})


def _gfind(code: str, scope: str, msg: str) -> Finding:
    return Finding(code, "global://cache_shardings", 0, 0, scope, msg)


def find_rope_concats(g) -> list:
    """Rope outputs: 2-input float concatenates tainted by cos/sin."""
    trig = [v for e in g.eqns_by_prim("cos", "sin") for v in e.outvars]
    if not trig:
        return []
    tainted = g.forward_taint(trig)
    return [e for e in g.eqns_by_prim("concatenate")
            if len(e.invars) == 2 and g.is_float(e.outvars[0])
            and all(g.find(v) in tainted for v in e.invars)]


def check_rope_constraints(g, finding) -> list:
    out = []
    for e in find_rope_concats(g):
        seen: set[int] = set()
        work = [(e.outvars[0], 0)]
        guarded, culprit = True, None
        while work:
            v, d = work.pop()
            v = g.find(v)
            if v in seen or d > 12:
                continue
            seen.add(v)
            for ce, _ in g.consumers(v):
                if ce.prim == "sharding_constraint":
                    continue                    # this path is covered
                if ce.prim in _BAD:
                    guarded, culprit = False, ce
                    break
                if ce.prim in _ALLOWED:
                    for ov in ce.outvars:
                        work.append((ov, d + 1))
            if not guarded:
                break
        if not guarded:
            out.append(finding(
                "post-rope",
                f"rope output (concat eqn{e.idx}@{'/'.join(e.path) or '<top>'}"
                f") reaches '{culprit.prim}' (eqn{culprit.idx}) without a "
                f"sharding_constraint — the attention contraction inherits "
                f"whatever sharding propagates into it, a partition-variant "
                f"float accumulation; re-constrain q/k right after rope"))
    return out


def check_paged_pool_specs(finding) -> list:
    """Drive cache_shardings over a representative paged + dense layout."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.parallel.sharding import cache_shardings

    sds = jax.ShapeDtypeStruct
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    tree = {
        "l0": {"attn": {
            "k": sds((16, 8, 2, 4), jnp.bfloat16),   # pool (P, bs, KH, Dh)
            "v": sds((16, 8, 2, 4), jnp.bfloat16),
            "bt": sds((4, 2), jnp.int32),            # per-slot block table
        }},
        "l1": {"attn": {                             # dense (B, C, KH, Dh)
            "k": sds((4, 32, 2, 4), jnp.bfloat16),
            "v": sds((4, 32, 2, 4), jnp.bfloat16),
            "pos": sds((4,), jnp.int32),
        }},
    }
    sh = cache_shardings(tree, mesh)
    out = []
    for nm in ("k", "v"):
        spec = sh["l0"]["attn"][nm].spec
        if spec[0] is not None or spec[1] is not None:
            out.append(finding(
                f"paged-pool/{nm}",
                f"cache_shardings shards the paged {nm} pool dims as "
                f"{spec} — block tables hold global block ids, so the pool "
                f"and block dims must stay DP-replicated or every lookup "
                f"reads another shard's rows"))
        if len(spec) > 2 and spec[2] != "model":
            out.append(finding(
                f"paged-pool/{nm}",
                f"paged {nm} pool kv-head dim is {spec[2]!r}, expected "
                f"'model' — the pool would be fully replicated over TP"))
        bt = sh["l0"]["attn"]["bt"].spec
        if bt and bt[0] not in (("data",), "data", None):
            out.append(finding(
                "paged-pool/bt",
                f"block table shards as {bt} — it is per-slot state and "
                f"must follow the batch (DP) layout"))
    dense = sh["l1"]["attn"]["k"].spec
    if dense[0] is None:
        out.append(finding(
            "dense-cache",
            f"dense cache k shards as {dense} — batch dim should shard "
            f"over the DP axes"))
    return out


class ShardingCoverageRule(TraceRule):
    code = "FTV106"
    name = "sharding-constraint-coverage"
    invariant = ("rope outputs are re-constrained before any contraction or "
                 "cache write; paged KV pools stay DP-replicated with kv "
                 "heads on 'model'")
    tags = frozenset({"mesh"})

    def check_global(self, env):
        def finding(scope, msg):
            return _gfind(self.code, scope, msg)
        return check_paged_pool_specs(finding)

    def check_target(self, ctx):
        g = ctx.graph
        if g is None:
            return []

        def finding(scope, msg):
            return ctx.finding(self.code, scope, msg)

        return check_rope_constraints(g, finding)


RULE = ShardingCoverageRule()
