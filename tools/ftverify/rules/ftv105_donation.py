"""FTV105 — buffer donation actually lands as aliasing.

``donate_argnums`` is a *request*: if the donated buffer's shape/dtype/
layout doesn't line up with an output — e.g. the function never returns the
updated caches — XLA silently copies instead of aliasing, and every decode
step pays a full cache copy.  jax only surfaces this as a warning at
*execution* time; this rule checks the lowered HLO at verify time: each
donated leaf the manifest declares must show up as a ``tf.aliasing_output``
input attribute, or — when output shardings are unspecified (mesh targets)
and jax defers the aliasing decision to XLA — as a ``jax.buffer_donor``
donor mark.  Either way the donated buffer is wired for reuse; zero
markers means jax dropped the donation at trace time (the warning path).
"""
from __future__ import annotations

from tools.ftverify.rules import TraceRule

ALIAS_MARKER = "tf.aliasing_output"
DONOR_MARKER = "jax.buffer_donor"


def count_aliased_inputs(hlo_text: str) -> int:
    return hlo_text.count(ALIAS_MARKER) + hlo_text.count(DONOR_MARKER)


class DonationRule(TraceRule):
    code = "FTV105"
    name = "donation-lands"
    invariant = ("every buffer a jitted executable donates is aliased to an "
                 "output in the lowered HLO (no silent copies)")
    tags = frozenset()

    def check_target(self, ctx):
        t = ctx.target
        if t.donated_leaves <= 0 or ctx.lowered is None:
            return []
        n = count_aliased_inputs(ctx.lowered)
        if n >= t.donated_leaves:
            return []
        return [ctx.finding(
            self.code, "donation",
            f"{t.donated_leaves} leaves are donated but only {n} lowered "
            f"with {ALIAS_MARKER}/{DONOR_MARKER} — donation is silently "
            f"dropped (the executable copies those buffers every call); "
            f"usually the function fails to return the updated buffers")]


RULE = DonationRule()
