"""Jaxpr dataflow graph for ftverify.

``build_graph`` flattens a ``ClosedJaxpr`` — descending into ``pjit`` /
``scan`` / ``while`` / ``cond`` / ``custom_*`` / ``pallas_call`` sub-jaxprs —
into one global def-use graph.  Sub-jaxpr binders are *aliased* to their
call-site operands with a union-find, so a backward walk from a truncation
shift inside a scan body escapes cleanly to the quantization boundary in the
caller, and a key var threaded through three helper jits still has one root.

The graph deliberately does **not** alias a scan carry's outputs back onto
its inputs: walks stay intra-iteration (rules reason about one step of the
loop), and cross-iteration questions ("does this draw vary per step?") are
answered by the explicit taint pass :meth:`Graph.scan_variant_roots`.

Vars are identified by ``id()`` of the binder object; ``jax.core.Literal``
operands get fresh negative ids (never aliased).  All rule-facing queries
(:meth:`producer`, :meth:`consumers`, :meth:`origin_sig`, the slice walks)
resolve through the union-find first.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any

import jax
import jax.numpy as jnp

# jaxpr types (jax 0.4.x public-ish surface)
from jax.core import ClosedJaxpr, Jaxpr, Literal  # noqa: F401

RNG_PRIMS = frozenset({
    "random_bits", "random_fold_in", "random_split", "random_wrap",
    "random_unwrap", "random_seed", "threefry2x32",
})

# shape/layout ops that forward their first operand's values unchanged —
# used by key-origin signatures and the rope/bf16 chain walks
PASSTHROUGH_PRIMS = frozenset({
    "reshape", "squeeze", "expand_dims", "broadcast_in_dim", "transpose",
    "slice", "rev", "copy", "stop_gradient", "convert_element_type",
    "random_wrap", "random_unwrap", "sharding_constraint",
})

# call-like primitives whose outputs alias a sub-jaxpr's outputs; concrete
# inner eqns take precedence over these in the producer map (see _finish)
CALL_LIKE_PRIMS = frozenset({
    "pjit", "closed_call", "core_call", "remat2", "checkpoint",
    "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
    "scan", "while", "cond", "pallas_call",
})


@dataclasses.dataclass
class GEqn:
    """One flattened equation: global var ids + the original eqn."""
    idx: int                     # position in Graph.eqns
    prim: str
    invars: list[int]            # global var ids (literals get fresh ids)
    outvars: list[int]
    eqn: Any                     # the JaxprEqn (params via eqn.params)
    path: tuple[str, ...]        # lexical nesting, e.g. ("pjit", "scan")
    scans: tuple[int, ...]       # idx of each enclosing scan GEqn


class Graph:
    def __init__(self) -> None:
        self.eqns: list[GEqn] = []
        self._parent: dict[int, int] = {}           # union-find
        self._aval: dict[int, Any] = {}             # root id -> aval
        self._literal: dict[int, Any] = {}          # var id -> literal value
        self._producers: dict[int, tuple[GEqn, int]] = {}
        self._consumers: dict[int, list[tuple[GEqn, int]]] = {}
        self.invar_ids: list[int] = []              # top-level invars
        self.const_ids: set[int] = set()            # top-level/inner consts
        # per-scan: inner binder ids of the carry+xs section (variant seeds)
        self.scan_variant_seeds: dict[int, list[int]] = {}
        self._ids = itertools.count(1)

    # -------------------------------------------------------- union-find --
    def _new_id(self, var=None) -> int:
        vid = next(self._ids)
        self._parent[vid] = vid
        if var is not None and hasattr(var, "aval"):
            self._aval[vid] = var.aval
        return vid

    def find(self, vid: int) -> int:
        p = self._parent
        root = vid
        while p[root] != root:
            root = p[root]
        while p[vid] != root:
            p[vid], vid = root, p[vid]
        return root

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self._parent[rb] = ra
            if ra not in self._aval and rb in self._aval:
                self._aval[ra] = self._aval[rb]

    # ------------------------------------------------------------ queries --
    def aval(self, vid: int):
        return self._aval.get(self.find(vid))

    def dtype(self, vid: int):
        a = self.aval(vid)
        return getattr(a, "dtype", None)

    def is_float(self, vid: int) -> bool:
        dt = self.dtype(vid)
        return dt is not None and jnp.issubdtype(dt, jnp.floating)

    def is_int(self, vid: int) -> bool:
        dt = self.dtype(vid)
        return dt is not None and jnp.issubdtype(dt, jnp.integer)

    def is_bool(self, vid: int) -> bool:
        dt = self.dtype(vid)
        return dt is not None and dt == jnp.bool_

    def is_literal(self, vid: int) -> bool:
        return self.find(vid) in self._literal

    def producer(self, vid: int) -> tuple[GEqn, int] | None:
        return self._producers.get(self.find(vid))

    def consumers(self, vid: int) -> list[tuple[GEqn, int]]:
        return self._consumers.get(self.find(vid), [])

    def eqns_by_prim(self, *prims: str) -> list[GEqn]:
        want = set(prims)
        return [e for e in self.eqns if e.prim in want]

    # ------------------------------------------------------------- builds --
    def _finish(self) -> None:
        """Key producer/consumer maps by union-find roots (post-aliasing).

        A call-site output is aliased to the sub-jaxpr's output binder, so
        its root has two producers: the call eqn (appended first) and the
        concrete inner eqn.  The *inner* one wins — backward walks then see
        the real op (and its round/bool boundaries) instead of jumping from
        a call eqn to its operands and skipping the body entirely."""
        for e in self.eqns:
            for i, vid in enumerate(e.outvars):
                r = self.find(vid)
                cur = self._producers.get(r)
                if cur is None or (cur[0].prim in CALL_LIKE_PRIMS
                                   and e.prim not in CALL_LIKE_PRIMS):
                    self._producers[r] = (e, i)
            for i, vid in enumerate(e.invars):
                self._consumers.setdefault(self.find(vid), []).append((e, i))

    # ------------------------------------------------------------- walks --
    def forward_taint(self, seed_ids, within_scan: int | None = None):
        """Set of var roots reachable forward from ``seed_ids``.  When
        ``within_scan`` is a scan eqn idx, propagation stays inside that
        scan's body."""
        tainted = {self.find(v) for v in seed_ids}
        work = list(tainted)
        while work:
            v = work.pop()
            for e, _ in self.consumers(v):
                if within_scan is not None and within_scan not in e.scans:
                    continue
                for out in e.outvars:
                    r = self.find(out)
                    if r not in tainted:
                        tainted.add(r)
                        work.append(r)
        return tainted

    def scan_variant_roots(self, scan_idx: int) -> set[int]:
        """Var roots inside scan body ``scan_idx`` that depend on the carry
        or the scanned-over xs (i.e. genuinely vary across iterations)."""
        seeds = self.scan_variant_seeds.get(scan_idx, [])
        return self.forward_taint(seeds, within_scan=scan_idx)

    def origin_sig(self, vid: int, _depth: int = 0):
        """Canonical origin of a value through pass-through ops.  Two vars
        with equal signatures carry the same bits (same producer, same
        slice/layout params) — the PRNG-key identity used by FTV103."""
        vid = self.find(vid)
        if _depth > 64:
            return ("deep", vid)
        if vid in self._literal:
            return ("lit", repr(self._literal[vid]))
        prod = self.producer(vid)
        if prod is None:
            return ("in", vid)
        e, out_idx = prod
        if e.prim in PASSTHROUGH_PRIMS and e.invars:
            params = e.eqn.params
            keyparams = tuple(sorted(
                (k, str(v)) for k, v in params.items()
                if k in ("start_indices", "limit_indices", "strides",
                         "permutation", "dimensions", "new_dtype",
                         "shape", "broadcast_dimensions", "sizes")))
            return (e.prim, keyparams,
                    self.origin_sig(e.invars[0], _depth + 1))
        return ("eqn", e.idx, out_idx)


# --------------------------------------------------------------------------
# flattening
# --------------------------------------------------------------------------
def _bind(g: Graph, env: dict[int, int], var) -> int:
    """Global id for a jaxpr var occurrence (Literal -> fresh id)."""
    if isinstance(var, Literal):
        vid = g._new_id()
        g._literal[vid] = var.val
        if hasattr(var, "aval"):
            g._aval[vid] = var.aval
        return vid
    key = id(var)
    if key not in env:
        env[key] = g._new_id(var)
    return env[key]


def _flatten(g: Graph, jaxpr: Jaxpr, env: dict[int, int],
             path: tuple[str, ...], scans: tuple[int, ...]) -> None:
    for eqn in jaxpr.eqns:
        in_ids = [_bind(g, env, v) for v in eqn.invars]
        out_ids = [_bind(g, env, v) for v in eqn.outvars]
        node = GEqn(len(g.eqns), eqn.primitive.name, in_ids, out_ids,
                    eqn, path, scans)
        g.eqns.append(node)
        _descend(g, node, path, scans)


def _sub_closed(params: dict, *keys: str):
    for k in keys:
        v = params.get(k)
        if isinstance(v, ClosedJaxpr):
            return v
        if isinstance(v, Jaxpr):
            return ClosedJaxpr(v, [])
    return None


def _enter(g: Graph, closed: ClosedJaxpr, env: dict[int, int]) -> tuple:
    """Fresh binder ids for a sub-jaxpr's constvars (+ record const ids)."""
    sub = closed.jaxpr
    for cv in sub.constvars:
        cid = _bind(g, env, cv)
        g.const_ids.add(g.find(cid))
    return sub


def _descend(g: Graph, node: GEqn, path: tuple[str, ...],
             scans: tuple[int, ...]) -> None:
    # Every descent opens a FRESH binding scope: jax dedupes traced
    # sub-jaxprs, so two pjit eqns (e.g. two bernoulli calls) can share one
    # inner Jaxpr *object* — binding its vars in a shared env would union
    # both call sites' operands onto one binder and merge unrelated values.
    prim, params = node.prim, node.eqn.params

    if prim == "scan":
        closed = params["jaxpr"]
        senv: dict[int, int] = {}
        sub = _enter(g, closed, senv)
        n_consts = params.get("num_consts", 0)
        sub_path, sub_scans = path + (prim,), scans + (node.idx,)
        in_ids = [_bind(g, senv, v) for v in sub.invars]
        for a, b in zip(node.invars, in_ids):
            g.union(a, b)
        # carry + xs binders are the per-iteration variant seeds
        g.scan_variant_seeds[node.idx] = in_ids[n_consts:]
        _flatten(g, sub, senv, sub_path, sub_scans)
        out_ids = [_bind(g, senv, v) for v in sub.outvars]
        for a, b in zip(node.outvars, out_ids):
            g.union(a, b)
        return

    if prim == "while":
        cn, bn = params.get("cond_nconsts", 0), params.get("body_nconsts", 0)
        benv: dict[int, int] = {}
        body = _enter(g, params["body_jaxpr"], benv)
        carry_ops = node.invars[cn + bn:]
        in_ids = [_bind(g, benv, v) for v in body.invars]
        for a, b in zip(node.invars[cn:cn + bn] + carry_ops, in_ids):
            g.union(a, b)
        _flatten(g, body, benv, path + (prim,), scans)
        out_ids = [_bind(g, benv, v) for v in body.outvars]
        for a, b in zip(node.outvars, out_ids):
            g.union(a, b)
        cenv: dict[int, int] = {}
        cond = _enter(g, params["cond_jaxpr"], cenv)
        cin = [_bind(g, cenv, v) for v in cond.invars]
        for a, b in zip(node.invars[:cn] + carry_ops, cin):
            g.union(a, b)
        _flatten(g, cond, cenv, path + ("while_cond",), scans)
        return

    if prim == "cond":
        ops = node.invars[1:]                       # invars[0] is the index
        for branch in params["branches"]:
            benv2: dict[int, int] = {}
            sub = _enter(g, branch, benv2)
            in_ids = [_bind(g, benv2, v) for v in sub.invars]
            if len(in_ids) == len(ops):
                for a, b in zip(ops, in_ids):
                    g.union(a, b)
            _flatten(g, sub, benv2, path + (prim,), scans)
            out_ids = [_bind(g, benv2, v) for v in sub.outvars]
            for a, b in zip(node.outvars, out_ids):
                g.union(a, b)
        return

    # generic call-like primitives: pjit, closed_call, remat2, custom_*
    closed = _sub_closed(params, "jaxpr", "call_jaxpr", "fun_jaxpr")
    if closed is None:
        return
    senv2: dict[int, int] = {}
    sub = _enter(g, closed, senv2)
    in_ids = [_bind(g, senv2, v) for v in sub.invars]
    # Alias binders to call-site operands only on an exact arity match (true
    # for pjit/closed_call; custom_vjp-style prims with implicit extras get
    # no aliasing — walks stop at the boundary, a conservative miss, rather
    # than risking wrong unions that chain-merge unrelated values).
    if len(in_ids) == len(node.invars):
        for a, b in zip(node.invars, in_ids):
            g.union(a, b)
    _flatten(g, sub, senv2, path + (prim,), scans)
    out_ids = [_bind(g, senv2, v) for v in sub.outvars]
    if len(out_ids) == len(node.outvars):
        for a, b in zip(node.outvars, out_ids):
            g.union(a, b)


def build_graph(closed: ClosedJaxpr) -> Graph:
    g = Graph()
    env: dict[int, int] = {}
    for v in closed.jaxpr.constvars:
        g.const_ids.add(g.find(_bind(g, env, v)))
    g.invar_ids = [_bind(g, env, v) for v in closed.jaxpr.invars]
    _flatten(g, closed.jaxpr, env, (), ())
    g._finish()
    return g


def trace_jaxpr(fn, *avals, **kw) -> ClosedJaxpr:
    """``jax.make_jaxpr`` over ShapeDtypeStructs (no execution)."""
    return jax.make_jaxpr(fn, **kw)(*avals)
