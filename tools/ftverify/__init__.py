"""ftverify — jaxpr-level verification of the fault-tolerance contracts.

``tools/ftlint`` checks the contracts it can see in the AST; this package
checks the ones that only exist in the traced IR.  All three sharded-serving
divergences fixed in PR 9 (legacy threefry partition-variance, excess-
precision elision of bf16 round-trips, sharding-dependent dispatch) were
invisible to source-level analysis — they are properties of the jaxpr and
the lowered HLO, so that is where ftverify verifies them: it traces the
repo's *real* executables (engine decode loop, scheduler prefill, the
fused_decode triplet, ``make_train_step``, the batched DSE oracle) with
``jax.make_jaxpr`` / ``jit(...).lower(...)`` and runs rules FTV101–FTV106
over the resulting dataflow graph.

Usage::

    python -m tools.ftverify --manifest default

Findings reuse the ``tools/ftlint`` conventions (same ``Finding`` record,
same line-number-free baseline keys, ``tools/ftverify/baseline.txt``
grandfather file, ``--write-report`` JSON artifact).  Rule catalogue and
the PR 9 bug each rule generalizes: docs/ftlint.md §ftverify.
"""
from tools.ftverify.core import VerifyEnv, main, verify_targets
from tools.ftverify.jaxpr_utils import Graph, build_graph
from tools.ftverify.rules import ALL_RULES

__all__ = ["ALL_RULES", "Graph", "VerifyEnv", "build_graph", "main",
           "verify_targets"]
