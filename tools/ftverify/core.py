"""ftverify runner: targets, per-target rule contexts, baseline, CLI.

Reuses the ``tools/ftlint`` findings layer (:class:`Finding`, baseline
loading/splitting) so both analyzers share one report/suppression idiom;
trace findings use a ``trace://<target>`` pseudo-path and line 0, which
keeps their baseline keys stable under any source edit.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import traceback
from pathlib import Path
from typing import Any, Callable

from tools.ftlint.core import Finding, load_baseline, split_baselined


@dataclasses.dataclass
class VerifyEnv:
    """Process facts the rules check against (read once per run)."""
    excess_precision_pinned: bool
    threefry_partitionable: bool
    n_devices: int

    @classmethod
    def capture(cls) -> "VerifyEnv":
        import jax
        return cls(
            excess_precision_pinned=("--xla_allow_excess_precision=false"
                                     in os.environ.get("XLA_FLAGS", "")),
            threefry_partitionable=bool(
                jax.config.jax_threefry_partitionable),
            n_devices=jax.device_count(),
        )


@dataclasses.dataclass
class Target:
    """One traced executable.  ``trace``/``lower`` are lazy thunks so a
    ``--rules`` filtered run only pays for the artifacts its rules read."""
    name: str
    tags: frozenset
    trace: Callable[[], Any] | None = None       # -> ClosedJaxpr
    lower: Callable[[], str] | None = None       # -> StableHLO text
    donated_leaves: int = 0                      # buffers expected to alias
    mesh: Any = None


class TargetCtx:
    """Lazy per-target analysis cache handed to each rule."""

    def __init__(self, target: Target, env: VerifyEnv):
        self.target = target
        self.env = env
        self._graph = None
        self._lowered = None

    @property
    def graph(self):
        if self._graph is None and self.target.trace is not None:
            from tools.ftverify.jaxpr_utils import build_graph
            self._graph = build_graph(self.target.trace())
        return self._graph

    @property
    def lowered(self) -> str | None:
        if self._lowered is None and self.target.lower is not None:
            self._lowered = self.target.lower()
        return self._lowered

    def finding(self, code: str, scope: str, message: str) -> Finding:
        return Finding(code, f"trace://{self.target.name}", 0, 0, scope,
                       message)


def verify_targets(targets, env: VerifyEnv | None = None,
                   rules=None) -> list[Finding]:
    """Run every rule over every target (plus each rule's global checks).

    A target that fails to trace/lower, or a rule that crashes, is reported
    as an FTV000 finding rather than aborting the run — a verifier that
    dies on the first broken target hides every other contract."""
    from tools.ftverify.rules import ALL_RULES
    env = env or VerifyEnv.capture()
    rules = ALL_RULES if rules is None else rules
    findings: list[Finding] = []
    for rule in rules:
        try:
            findings.extend(rule.check_global(env))
        except Exception as e:
            findings.append(Finding(
                "FTV000", f"rule://{rule.code}", 0, 0, "global",
                f"global check crashed: {type(e).__name__}: {e}"))
    for t in targets:
        ctx = TargetCtx(t, env)
        for rule in rules:
            if not rule.applies(t):
                continue
            try:
                findings.extend(rule.check_target(ctx))
            except Exception as e:
                traceback.print_exc(file=sys.stderr)
                findings.append(ctx.finding(
                    "FTV000", rule.code,
                    f"{rule.code} check failed on this target: "
                    f"{type(e).__name__}: {e}"))
    findings.sort(key=lambda f: (f.path, f.code, f.scope, f.message))
    return findings


# -------------------------------------------------------------------- CLI --
def main(argv=None) -> int:
    from tools.ftverify.rules import ALL_RULES
    ap = argparse.ArgumentParser(
        prog="python -m tools.ftverify",
        description="Trace-level verification of the repo's fault-tolerance "
                    "contracts (see docs/ftlint.md §ftverify).")
    ap.add_argument("--manifest", default="default", choices=("default",),
                    help="target manifest to trace")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule codes to run (default: all)")
    ap.add_argument("--baseline",
                    default=str(Path(__file__).parent / "baseline.txt"))
    ap.add_argument("--no-baseline", action="store_true",
                    help="report baselined findings as errors too")
    ap.add_argument("--write-report", metavar="PATH",
                    help="write a JSON report (CI artifact)")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--expect", metavar="CODE", default=None,
                    help="invert the exit status around CODE: succeed iff "
                         "at least one new CODE finding fires (CI exercises "
                         "the unpinned-flag arm this way)")
    ap.add_argument("--no-pin-excess-precision", action="store_true",
                    help="(parsed in __main__ before jax loads) do not pin "
                         "--xla_allow_excess_precision=false for this run")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in ALL_RULES:
            print(f"{r.code}  {r.name}")
            print(f"        invariant: {r.invariant}")
        return 0

    rules = ALL_RULES
    if args.rules:
        want = {c.strip() for c in args.rules.split(",") if c.strip()}
        rules = tuple(r for r in ALL_RULES if r.code in want)
        unknown = want - {r.code for r in rules}
        if unknown:
            print(f"[ftverify] unknown rule(s): {sorted(unknown)}",
                  file=sys.stderr)
            return 2

    from tools.ftverify.targets import default_manifest
    # build the manifest BEFORE capturing the env: constructing targets
    # imports the repo (repro.core.faults pins jax_threefry_partitionable at
    # import), so capture-then-build would read the flag pre-pin and FTV102
    # would report the tracing processes' state wrongly
    targets = default_manifest()
    env = VerifyEnv.capture()
    findings = verify_targets(targets, env, rules)
    baseline = set() if args.no_baseline else load_baseline(
        Path(args.baseline))
    new, old = split_baselined(findings, baseline)

    for f in new:
        print(f.render())
    if old:
        print(f"[ftverify] {len(old)} baselined finding(s) not shown "
              f"(--no-baseline to list)", file=sys.stderr)
    stale = baseline - {f.baseline_key() for f in findings}
    if stale:
        print(f"[ftverify] note: {len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} (fixed or moved — "
              "prune tools/ftverify/baseline.txt)", file=sys.stderr)

    if args.write_report:
        def row(f: Finding) -> dict:
            d = dataclasses.asdict(f)
            d["key"] = f.baseline_key()
            return d
        report = {
            "env": dataclasses.asdict(env),
            "targets": [t.name for t in targets],
            "rules": [r.code for r in rules],
            "new": [row(f) for f in new],
            "baselined": [row(f) for f in old],
            "stale_baseline": sorted(stale),
        }
        Path(args.write_report).write_text(json.dumps(report, indent=2))

    n_exp = ""
    if args.expect:
        hits = [f for f in new if f.code == args.expect]
        others = [f for f in new if f.code != args.expect]
        ok = bool(hits) and not others
        n_exp = (f", expected {args.expect}: "
                 f"{'fired' if hits else 'DID NOT FIRE'}"
                 + (f" (+{len(others)} unexpected)" if others else ""))
        status = 0 if ok else 1
    else:
        status = 1 if new else 0
    print(f"[ftverify] {len(targets)} targets, {len(rules)} rules: "
          f"{'clean' if not new else f'{len(new)} finding(s)'}{n_exp}",
          file=sys.stderr)
    return status
