"""``python -m tools.ftverify`` entry point.

Environment pins must land before jax initializes, so they happen here,
not in ``core.main``:

* ``--xla_allow_excess_precision=false`` — the FTV102 contract flag — is
  appended to ``XLA_FLAGS`` unless the caller passes
  ``--no-pin-excess-precision`` (the CI arm that proves FTV102 fires) or
  already set the flag themselves;
* the emulated 8-device mesh (``--xla_force_host_platform_device_count``)
  so the mesh targets trace with real multi-device shardings when the host
  has a lone CPU.
"""
import os
import sys
from pathlib import Path

# a repo checkout runs without PYTHONPATH=src
_SRC = str(Path(__file__).resolve().parents[2] / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

_argv = sys.argv[1:]
_flags = os.environ.get("XLA_FLAGS", "")
if ("--no-pin-excess-precision" not in _argv
        and "--xla_allow_excess_precision" not in _flags):
    _flags = (_flags + " --xla_allow_excess_precision=false").strip()
if "--xla_force_host_platform_device_count" not in _flags:
    _flags = (_flags + " --xla_force_host_platform_device_count=8").strip()
os.environ["XLA_FLAGS"] = _flags

from tools.ftverify.core import main  # noqa: E402

raise SystemExit(main(_argv))
