"""AdamW with warmup+cosine schedule and global-norm clipping.

Optimizer moments live in a configurable dtype (fp32 default; bf16 for the
largest MoE archs so 235B-scale state fits v5e HBM — see RunConfig.adam_dtype)
and inherit the parameter sharding, so the optimizer is ZeRO-sharded for free.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    dtype: str = "float32"


def lr_at(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params, cfg: AdamWConfig):
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.dtype]
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        p2 = p.astype(jnp.float32) - lr * (u + decay)
        return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
