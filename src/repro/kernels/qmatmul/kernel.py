"""Pallas TPU kernel: int8 x int8 DLA matmul with 24-bit saturating
accumulator and Q_scale-constrained 8-bit window truncation.

Tiling: (bm x bk) @ (bk x bn) MXU tiles with an int32 VMEM accumulator
scratch; K is the innermost (sequential) grid dim.  int8 operands hit the
MXU's native int8 path with int32 accumulation on real TPUs; interpret mode
executes the same program on CPU for validation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

ACC_BITS = 24
OUT_BITS = 8


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, t: int, nk: int, acc_bits: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _finish():
        lo = -(1 << (acc_bits - 1))
        hi = (1 << (acc_bits - 1)) - 1
        acc = jnp.clip(acc_ref[...], lo, hi)        # saturating 24-bit acc
        half = (1 << (t - 1)) if t > 0 else 0
        r = (acc + half) >> t                        # window truncation
        qmax = (1 << (OUT_BITS - 1)) - 1
        o_ref[...] = jnp.clip(r, -qmax - 1, qmax).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("t", "bm", "bn", "bk",
                                             "acc_bits", "interpret"))
def qmatmul(xq, wq, t: int, bm: int = 128, bn: int = 128, bk: int = 128,
            acc_bits: int = ACC_BITS, interpret: bool = True):
    """xq: (M, K) int8; wq: (K, N) int8 -> (M, N) int8."""
    M, K = xq.shape
    _, N = wq.shape
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K)
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, t=t, nk=nk, acc_bits=acc_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xq, wq)
