"""jit'd wrapper for the quantized DLA matmul kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import quantization as Q
from repro.kernels.qmatmul.kernel import qmatmul


@partial(jax.jit, static_argnames=("t", "interpret"))
def quant_linear(x, w, t: int, interpret: bool = True):
    """Float-in/float-out linear through the int8 DLA datapath kernel."""
    xq, sx = Q.quantize(x)
    wq, sw = Q.quantize(w)
    yq = qmatmul(xq.astype(jnp.int8), wq.astype(jnp.int8), t,
                 interpret=interpret)
    return yq.astype(jnp.float32) * (sx * sw * (2.0 ** t))
