"""Pure-jnp oracle for the quantized DLA matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp

ACC_BITS = 24
OUT_BITS = 8


def saturate(acc, bits=ACC_BITS):
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    return jnp.clip(acc, lo, hi)


def truncate(acc, t: int, out_bits=OUT_BITS):
    half = (1 << (t - 1)) if t > 0 else 0
    r = (acc + half) >> t
    qmax = (1 << (out_bits - 1)) - 1
    return jnp.clip(r, -qmax - 1, qmax)


def qmatmul_ref(xq, wq, t: int, acc_bits: int = ACC_BITS):
    """int8-valued inputs -> int8-valued output through a saturating
    `acc_bits` accumulator and an 8-bit window at LSB `t`."""
    acc = jnp.matmul(xq.astype(jnp.int32), wq.astype(jnp.int32),
                     preferred_element_type=jnp.int32)
    return truncate(saturate(acc, acc_bits), t).astype(jnp.int8)
