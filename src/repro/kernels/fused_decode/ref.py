"""Composed-op oracle for the fused inject→protect→qmatmul decode kernel.

This is the `fault_inject ∘ protect ∘ qmatmul` composition written as plain
jnp over the *same operands the kernel sees*: quantized integers plus
pre-drawn packed flip words (bit ``b`` of a flip word = flip event for bit
``b`` — see ``repro.core.faults.flip_word``).  All fault randomness is
resolved before this function; everything inside is deterministic integer
math, which is what makes kernel-vs-reference parity a bit-exact equality
instead of a tolerance check.

The datapath (identical to ``ft.api._protect_reference`` after its own
quantize/key-schedule stage):

  int8 x int8 → int32 accumulate → 24-bit saturate → truncation LSB ``t``
  from the accumulator's integer bit-length (Q_scale-constrained) → 8-bit
  round-to-nearest window → XOR output flip word → sign-extend
  [→ DPPU clean recompute, same ``t``, own flip word, select important]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quantization as Q

ACC_BITS = Q.ACC_BITS
OUT_BITS = Q.OUT_BITS


def sign_extend8(u: jax.Array, bits: int = OUT_BITS) -> jax.Array:
    """Reinterpret the low `bits` of int32 `u` as two's complement."""
    sign = 1 << (bits - 1)
    return jnp.where((u & sign) != 0, u - (1 << bits), u)


def faulty_weights(wq: jax.Array, wflips: jax.Array,
                   bits: int = OUT_BITS) -> jax.Array:
    """Apply packed per-row weight flip words: (K, N) x (M, K, N) → (M, K, N)."""
    uw = (wq[None, :, :].astype(jnp.int32) & ((1 << bits) - 1)) ^ wflips
    return sign_extend8(uw, bits)


def fused_ref(xq: jax.Array, wq: jax.Array, oflips: jax.Array, q_scale, *,
              per_row: bool = False,
              wflips: jax.Array | None = None,
              wq_clean: jax.Array | None = None,
              dflips: jax.Array | None = None,
              imp: jax.Array | None = None,
              acc_bits: int = ACC_BITS, out_bits: int = OUT_BITS):
    """The fused kernel's exact contract, as composed reference ops.

    Args:
      xq: (M, K) int8-valued activations.  wq: (K, N) int8-valued weights —
        already weight-faulted in shared-fault mode.
      oflips: (M, N) int32 packed output flip words (protection already
        folded into the draw via the protected mask).
      q_scale: minimum truncation LSB (int or traced int32 — the dyn leaf).
      per_row: per-row truncation LSB (serving batches) vs one global t.
      wflips: optional (M, K, N) packed *per-row* weight flip words; when
        given, row m sees its own faulty weight matrix (continuous-batching
        weight faults — each request keeps an independent stream).
      wq_clean: clean weights for the DPPU recompute when `wq` is faulty
        (shared-fault mode); defaults to `wq`.
      dflips/imp: DPPU flip words (M, N) and important-channel mask (N,);
        both present ⇔ the policy recomputes important channels.
    Returns:
      (yq, t): int32 int8-valued outputs (M, N) and the truncation LSB —
      (M, 1) when per_row else scalar.
    """
    xq = xq.astype(jnp.int32)
    wq = wq.astype(jnp.int32)
    if wflips is not None:
        wf = faulty_weights(wq, wflips, out_bits)
        acc = jax.vmap(lambda a, b: jnp.matmul(
            a, b, preferred_element_type=jnp.int32))(xq, wf)
    else:
        acc = jnp.matmul(xq, wq, preferred_element_type=jnp.int32)
    acc = Q.saturate(acc, acc_bits)
    absmax = (jnp.max(jnp.abs(acc), axis=1, keepdims=True) if per_row
              else jnp.max(jnp.abs(acc)))
    t = Q.choose_trunc_lsb(absmax, out_bits=out_bits, q_scale=q_scale,
                           acc_bits=acc_bits)
    yq = Q.truncate_acc(acc, t, out_bits)
    mask_all = (1 << out_bits) - 1
    y = sign_extend8((yq & mask_all) ^ oflips, out_bits)

    if dflips is not None:
        wc = wq if wq_clean is None else wq_clean.astype(jnp.int32)
        acc_d = Q.saturate(jnp.matmul(xq, wc,
                                      preferred_element_type=jnp.int32),
                           acc_bits)
        yq_d = Q.truncate_acc(acc_d, t, out_bits)
        y_d = sign_extend8((yq_d & mask_all) ^ dflips, out_bits)
        y = jnp.where(imp[None, :] != 0, y_d, y)
    return y, t
