"""Pallas TPU kernel: fused inject→protect→qmatmul for the decode hot path.

One pass over the integer datapath replaces the three-dispatch composition
(`kernels/fault_inject` + `kernels/protected_mm` + `kernels/qmatmul`):

  int8 MXU matmul → int32 accumulate over K (sequential grid) → 24-bit
  saturate → truncation LSB ``t`` derived *in-kernel* from the accumulator's
  integer bit-length (Q_scale-constrained, per-row or global) → 8-bit
  round-to-nearest window → XOR pre-drawn packed flip words → sign-extend
  [→ DPPU recompute on a second clean accumulator, select important] → int8.

Differences from ``protected_mm`` that make this the serving kernel:

  * Fault randomness arrives as *packed* flip words (one int32 carries all 8
    bit planes, protection already folded into the draw) instead of 8 uint32
    planes per stream — 8x less HBM traffic per fault stream, and the kernel
    epilogue is a single XOR instead of per-bit threshold compares.
  * ``t`` is computed from data inside the kernel (integer popcount over
    threshold compares), so the kernel works under jit/scan with traced
    operands — no statically calibrated ``t``, no per-layer recompiles.
  * ``q_scale`` is an SMEM-style scalar operand, so traced dyn-leaf
    overrides (the batched-DSE path) ride straight into the kernel.
  * Optional per-row weight flip words give each batch row its own faulty
    weight view — the capability that lifts the scheduler's
    ``weight_faults=False`` restriction.

Decode-shaped by design: the whole (M, N) accumulator lives in VMEM and the
grid is sequential over K only, which assumes small M (a decode batch) and
moderate N.  Prefill-sized GEMMs should keep using the tiled kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

ACC_BITS = 24
OUT_BITS = 8


def _sign_extend(u, bits):
    sign = 1 << (bits - 1)
    return jnp.where((u & sign) != 0, u - (1 << bits), u)


def _trunc(acc, t, out_bits):
    half = jnp.where(t > 0, 1 << jnp.maximum(t - 1, 0), 0)
    qmax = (1 << (out_bits - 1)) - 1
    return jnp.clip((acc + half) >> t, -qmax - 1, qmax)


def _kernel(*refs, nk: int, per_row: bool, dppu_src: str, perrow_wf: bool,
            bits: int, acc_bits: int, out_bits: int):
    it = iter(refs)
    x_ref = next(it)
    w_ref = next(it)
    wcl_ref = next(it) if dppu_src == "wcl" else None
    wflips_ref = next(it) if perrow_wf else None
    oflip_ref = next(it)
    dflip_ref = next(it) if dppu_src != "none" else None
    imp_ref = next(it) if dppu_src != "none" else None
    qs_ref = next(it)
    o_ref = next(it)
    t_ref = next(it)
    acc_ref = next(it)
    accd_ref = next(it) if dppu_src in ("w", "wcl") else None

    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if accd_ref is not None:
            accd_ref[...] = jnp.zeros_like(accd_ref)

    if perrow_wf:
        # Row-private faulty weights: XOR the packed flip word into the
        # shared weight tile, sign-extend, and accumulate on the VPU
        # (decode M is small, so the broadcast product is cheap).
        w = w_ref[...].astype(jnp.int32)
        wf = _sign_extend((w[None, :, :] & ((1 << bits) - 1))
                          ^ wflips_ref[...], bits)
        x = x_ref[...].astype(jnp.int32)
        acc_ref[...] += jnp.sum(x[:, :, None] * wf, axis=1)
    else:
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    if dppu_src == "w":
        accd_ref[...] += jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    elif dppu_src == "wcl":
        accd_ref[...] += jax.lax.dot_general(
            x_ref[...], wcl_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(0) == nk - 1)
    def _finish():
        lo = -(1 << (acc_bits - 1))
        hi = (1 << (acc_bits - 1)) - 1
        acc = jnp.clip(acc_ref[...], lo, hi)
        m = acc.shape[0]
        if per_row:
            absmax = jnp.max(jnp.abs(acc), axis=1, keepdims=True)  # (M, 1)
        else:
            absmax = jnp.max(jnp.abs(acc))
        # t from the accumulator's integer bit-length: popcount over
        # threshold compares — bit-identical to Q.choose_trunc_lsb.
        a = jnp.maximum(absmax, 1)
        need = jnp.zeros_like(a)
        for b in range(acc_bits):
            need += (a >= (1 << b)).astype(jnp.int32)
        t = jnp.maximum(need - (out_bits - 1), 0)
        t = jnp.clip(t, qs_ref[0, 0], acc_bits - out_bits)

        mask_all = (1 << bits) - 1
        uy = (_trunc(acc, t, out_bits) & mask_all) ^ oflip_ref[...]
        if dppu_src != "none":
            acc_d = acc if dppu_src == "reuse" else jnp.clip(
                accd_ref[...], lo, hi)
            ud = (_trunc(acc_d, t, out_bits) & mask_all) ^ dflip_ref[...]
            uy = jnp.where(imp_ref[...] != 0, ud, uy)
        o_ref[...] = _sign_extend(uy, bits).astype(jnp.int8)
        t_ref[...] = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (m, 1))


@functools.partial(jax.jit, static_argnames=(
    "per_row", "dppu_src", "perrow_wf", "bk", "bits", "acc_bits", "out_bits",
    "interpret"))
def fused_decode(xq, wq, oflips, q_scale, *, wq_clean=None, wflips=None,
                 dflips=None, imp=None, per_row: bool = False,
                 dppu_src: str = "none", perrow_wf: bool = False,
                 bk: int = 128, bits: int = 8, acc_bits: int = ACC_BITS,
                 out_bits: int = OUT_BITS, interpret: bool = True):
    """One fused decode step.

    Args:
      xq: (M, K) int8.  wq: (K, N) int8 (pre-faulted in shared-fault mode).
      oflips: (M, N) int32 packed output flip words.
      q_scale: (1, 1) int32 — minimum truncation LSB (traceable dyn leaf).
      wq_clean: (K, N) int8 clean weights (dppu_src="wcl" only).
      wflips: (M, K, N) int32 per-row weight flip words (perrow_wf only).
      dflips: (M, N) int32 DPPU flip words; imp: (1, N) int32 mask
        (dppu_src != "none" only).
      per_row: per-row truncation LSB instead of one global t.
      dppu_src: "none" | "reuse" (clean acc == faulty acc: no weight
        faults) | "w" (recompute from `wq`, which is clean in per-row
        weight-fault mode) | "wcl" (recompute from `wq_clean`).
    Returns:
      (y, t): (M, N) int8 outputs and (M, 1) int32 truncation LSBs
      (all rows equal when per_row=False).
    """
    M, K = xq.shape
    _, N = wq.shape
    assert M % 8 == 0 and N % 128 == 0 and K % bk == 0, (
        "fused_decode operands must be tile-aligned (pad in ops.py)")
    nk = K // bk
    grid = (nk,)

    operands = [xq, wq]
    in_specs = [
        pl.BlockSpec((M, bk), lambda k: (0, k)),
        pl.BlockSpec((bk, N), lambda k: (k, 0)),
    ]
    if dppu_src == "wcl":
        operands.append(wq_clean)
        in_specs.append(pl.BlockSpec((bk, N), lambda k: (k, 0)))
    if perrow_wf:
        operands.append(wflips)
        in_specs.append(pl.BlockSpec((M, bk, N), lambda k: (0, k, 0)))
    operands.append(oflips)
    in_specs.append(pl.BlockSpec((M, N), lambda k: (0, 0)))
    if dppu_src != "none":
        operands.extend([dflips, imp])
        in_specs.extend([pl.BlockSpec((M, N), lambda k: (0, 0)),
                         pl.BlockSpec((1, N), lambda k: (0, 0))])
    operands.append(q_scale)
    in_specs.append(pl.BlockSpec((1, 1), lambda k: (0, 0)))

    scratch = [pltpu.VMEM((M, N), jnp.int32)]
    if dppu_src in ("w", "wcl"):
        scratch.append(pltpu.VMEM((M, N), jnp.int32))

    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, per_row=per_row, dppu_src=dppu_src,
                          perrow_wf=perrow_wf, bits=bits, acc_bits=acc_bits,
                          out_bits=out_bits),
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((M, N), lambda k: (0, 0)),
                   pl.BlockSpec((M, 1), lambda k: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, N), jnp.int8),
                   jax.ShapeDtypeStruct((M, 1), jnp.int32)],
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*operands)
