"""``fused_protect_linear`` — the full ``protect_linear`` semantics on the
fused decode kernel (``backend="fused"``).

The split of responsibilities that keeps this bit-exact with the reference
backend:

  * *Outside the kernel* (here): quantization (the only float↔int
    boundaries), the policy's key schedule — identical splits and draw
    shapes to ``ft.api._protect_reference`` — and the packing of every
    fault draw into int32 flip words (``repro.core.faults.flip_word``).
  * *Inside the kernel*: pure integer math on those operands — matmul,
    saturate, in-kernel truncation-LSB selection, XOR, select.

Because the draws are identical and the integer datapath is deterministic,
``fused_protect_linear(key, ...) == _protect_reference(key, ...)`` holds
bitwise for every registry policy, global or per-row keys, with or without
weight faults, and with traced ``dyn`` knob overrides.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import faults
from repro.core import quantization as Q
from repro.kernels.fused_decode.kernel import fused_decode


def _pad_to(a: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [(0, -s % m) for s, m in zip(a.shape, mults)]
    if any(p for _, p in pads):
        a = jnp.pad(a, pads)
    return a


@partial(jax.jit, static_argnames=("layer_protected", "interpret"))
def fused_protect_linear(key: jax.Array, x: jax.Array, w: jax.Array,
                         policy, important: jax.Array | None = None, *,
                         layer_protected: bool = True, dyn=None,
                         interpret: bool = True) -> jax.Array:
    """Fault-tolerant linear on the fused kernel: float in/out.

    Accepts everything ``protect_linear`` does — a single key or an (M, 2)
    per-row key batch, all registry policies (weight faults included, also
    per-row), ``important`` masks, ``layer_protected`` and traced ``dyn``
    overrides — and matches the reference backend bit-for-bit.
    """
    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1])
    m, n = x2.shape[0], w.shape[1]
    per_row = getattr(key, "ndim", 1) == 2
    if per_row:
        ks = jax.vmap(lambda k: jax.random.split(k, 3))(key)   # (M, 3, 2)
        kw, ka, kd = ks[:, 0], ks[:, 1], ks[:, 2]
    else:
        kw, ka, kd = jax.random.split(key, 3)
    alg, arch, circ = policy.algorithm, policy.arch, policy.circuit
    dyn = dyn or {}
    ib_th = dyn.get("ib_th", circ.ib_th)
    nb_th = dyn.get("nb_th", circ.nb_th)
    q_scale = dyn.get("q_scale", alg.q_scale)

    xq, sx = Q.quantize(x2, axis=1 if per_row else None)
    wq, sw = Q.quantize(w)

    # weight-fault flip words — same draws as inject_weight_faults
    wq_k, wq_clean, wflips, perrow_wf = wq, None, None, False
    if policy.weight_faults:
        if per_row:
            wflips = jax.vmap(lambda k: faults.flip_word(
                k, wq.shape, policy.ber, Q.OUT_BITS))(kw)      # (M, K, N)
            perrow_wf = True
        else:
            wq_k = faults.inject_weight_faults(kw, wq, policy.ber)
            wq_clean = wq

    # output flip words — protection folded into the draw's residual rates
    imp = jnp.zeros((n,), bool) if important is None else important
    protect = jnp.where(imp, ib_th, nb_th).astype(jnp.int32)
    if arch.whole_layer_tmr and layer_protected:
        protect = jnp.full((n,), Q.OUT_BITS, jnp.int32)
    pmask = faults.protect_mask(protect, Q.OUT_BITS)
    if per_row:
        oflips = jax.vmap(lambda k: faults.flip_word(
            k, (n,), policy.ber, Q.OUT_BITS, pmask))(ka)
    else:
        oflips = faults.flip_word(ka, (m, n), policy.ber, Q.OUT_BITS, pmask)

    # DPPU recompute flip words
    dflips, imp_arr, dppu_src = None, None, "none"
    if arch.recompute and important is not None:
        dmask = faults.protect_mask(
            jnp.broadcast_to(jnp.asarray(ib_th, jnp.int32), (n,)), Q.OUT_BITS)
        if per_row:
            dflips = jax.vmap(lambda k: faults.flip_word(
                k, (n,), policy.ber, Q.OUT_BITS, dmask))(kd)
        else:
            dflips = faults.flip_word(kd, (m, n), policy.ber, Q.OUT_BITS,
                                      dmask)
        imp_arr = important.astype(jnp.int32)
        if perrow_wf:
            dppu_src = "w"          # wq operand is clean; flips are separate
        elif wq_clean is not None:
            dppu_src = "wcl"        # wq operand pre-faulted; recompute clean
        else:
            dppu_src = "reuse"      # no weight faults: clean acc == acc

    # tile-align (zero pads are exact for the integer datapath; padded rows
    # have absmax 0 so they never move a per-row or global t)
    xq8 = _pad_to(xq.astype(jnp.int8), (8, 128))
    wq8 = _pad_to(wq_k.astype(jnp.int8), (128, 128))
    mp, np_ = xq8.shape[0], wq8.shape[1]
    kw_args = dict(per_row=per_row, dppu_src=dppu_src, perrow_wf=perrow_wf,
                   interpret=interpret)
    if dppu_src == "wcl":
        kw_args["wq_clean"] = _pad_to(wq_clean.astype(jnp.int8), (128, 128))
    if perrow_wf:
        kw_args["wflips"] = _pad_to(wflips, (8, 128, 128))
    if dppu_src != "none":
        kw_args["dflips"] = _pad_to(dflips, (8, 128))
        kw_args["imp"] = _pad_to(imp_arr, (128,)).reshape(1, np_)
    qs = jnp.asarray(q_scale, jnp.int32).reshape(1, 1)

    yq8, tcol = fused_decode(xq8, wq8, _pad_to(oflips, (8, 128)), qs,
                             **kw_args)
    yq = yq8[:m, :n].astype(jnp.int32)
    t = tcol[:m] if per_row else tcol[0, 0]
    scale = sx * sw * (2.0 ** t.astype(jnp.float32))
    y = yq.astype(jnp.float32) * scale
    return y.reshape(*orig_shape[:-1], n)
