"""jit'd wrapper: float-in/float-out fault-tolerant linear on the fused
FlexHyCA kernel — the TPU-optimized twin of repro.core.flexhyca.ft_linear.

The truncation LSB `t` is per-layer deployment configuration on the DLA
(chosen once at calibration), so it is a static argument here; use
``calibrate_t`` to derive it from sample data.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import quantization as Q
from repro.kernels.fault_inject.ops import random_planes
from repro.kernels.protected_mm.kernel import protected_mm


def calibrate_t(x, w, q_scale: int = 7) -> int:
    """Pick the per-layer truncation LSB from calibration data."""
    xq, _ = Q.quantize(x)
    wq, _ = Q.quantize(w)
    acc = Q.saturate(jnp.matmul(xq, wq, preferred_element_type=jnp.int32))
    return int(Q.choose_trunc_lsb(jnp.max(jnp.abs(acc)), q_scale=q_scale))


@partial(jax.jit, static_argnames=("t", "ber", "ib", "nb", "interpret"))
def ft_linear_fused(key, x, w, important, *, t: int, ber: float, ib: int = 2,
                    nb: int = 1, interpret: bool = True):
    """x: (M, K) float; w: (K, N) float; important: (N,) bool."""
    xq, sx = Q.quantize(x)
    wq, sw = Q.quantize(w)
    k1, k2 = jax.random.split(key)
    rnd_o = random_planes(k1, x.shape[:1] + w.shape[1:])
    rnd_i = random_planes(k2, x.shape[:1] + w.shape[1:])
    yq = protected_mm(xq.astype(jnp.int8), wq.astype(jnp.int8), rnd_o, rnd_i,
                      important.astype(jnp.int32), t=t, ber=ber, ib=ib, nb=nb,
                      interpret=interpret)
    scale = sx * sw * (2.0 ** t)
    return yq.astype(jnp.float32) * scale
