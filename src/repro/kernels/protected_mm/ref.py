"""Pure-jnp oracle for the fused FlexHyCA protected matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.fault_inject.ref import inject_ref
from repro.kernels.qmatmul.ref import qmatmul_ref


def protected_mm_ref(xq, wq, rnd_ord, rnd_imp, imp_mask, *, t: int,
                     ber: float, ib: int, nb: int, bits: int = 8):
    """FlexHyCA PE-array semantics:

      - every output computed on the 2-D array: faults at `ber` with the top
        `nb` bits TMR-protected,
      - important output channels recomputed on the DPPU: independent fault
        draw with the top `ib` bits protected; DPPU result overrides.
    """
    yq = qmatmul_ref(xq, wq, t).astype(jnp.int32)
    n = wq.shape[1]
    prot_ord = jnp.full((n,), nb, jnp.int32)
    prot_imp = jnp.full((n,), ib, jnp.int32)
    y_ord = inject_ref(yq, rnd_ord, prot_ord, ber, bits)
    y_imp = inject_ref(yq, rnd_imp, prot_imp, ber, bits)
    return jnp.where(imp_mask[None, :] != 0, y_imp, y_ord).astype(jnp.int8)
