"""Pallas TPU kernel: the FlexHyCA PE array as one fused op.

int8 x int8 MXU matmul -> 24-bit saturating accumulate -> Q_scale-constrained
8-bit window -> soft-error injection with selective protection:

  * ordinary channels: 2-D-array result, top-NB_TH bits TMR'd
  * important channels (mask input): DPPU recompute (independent fault draw),
    top-IB_TH bits TMR'd, overrides the array result

This is the TPU-native rendering of the paper's architecture+circuit layers:
the "DPPU" recompute costs one extra fault-draw + select inside the tile that
is already VMEM-resident, instead of a second pass over HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

ACC_BITS = 24
OUT_BITS = 8


def _flip(ux, rnd_ref, prot, thresh, bits):
    flips = jnp.zeros_like(ux)
    for b in range(bits):
        flip = rnd_ref[b] < thresh
        unprot = b < (bits - prot)
        flips = flips | jnp.where(flip & unprot, 1 << b, 0)
    return ux ^ flips


def _kernel(x_ref, w_ref, rnd_o_ref, rnd_i_ref, imp_ref, o_ref, acc_ref, *,
            t: int, ber: float, ib: int, nb: int, bits: int, nk: int,
            acc_bits: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _finish():
        lo = -(1 << (acc_bits - 1))
        hi = (1 << (acc_bits - 1)) - 1
        acc = jnp.clip(acc_ref[...], lo, hi)
        half = (1 << (t - 1)) if t > 0 else 0
        qmax = (1 << (OUT_BITS - 1)) - 1
        yq = jnp.clip((acc + half) >> t, -qmax - 1, qmax)

        thresh = jnp.uint32(min(int(ber * (1 << 32)), (1 << 32) - 1))
        mask_all = (1 << bits) - 1
        ux = yq & mask_all
        y_ord = _flip(ux, rnd_o_ref, jnp.int32(nb), thresh, bits)
        y_imp = _flip(ux, rnd_i_ref, jnp.int32(ib), thresh, bits)
        uy = jnp.where(imp_ref[...] != 0, y_imp, y_ord)
        sign = 1 << (bits - 1)
        sy = jnp.where((uy & sign) != 0, uy - (1 << bits), uy)
        o_ref[...] = sy.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=(
    "t", "ber", "ib", "nb", "bits", "bm", "bn", "bk", "acc_bits",
    "interpret"))
def protected_mm(xq, wq, rnd_ord, rnd_imp, imp_mask, *, t: int, ber: float,
                 ib: int = 2, nb: int = 1, bits: int = 8,
                 bm: int = 128, bn: int = 128, bk: int = 128,
                 acc_bits: int = ACC_BITS, interpret: bool = True):
    """xq (M,K) int8; wq (K,N) int8; rnd_* (bits,M,N) uint32;
    imp_mask (N,) int32 -> (M,N) int8."""
    M, K = xq.shape
    _, N = wq.shape
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, t=t, ber=ber, ib=ib, nb=nb, bits=bits,
                          nk=nk, acc_bits=acc_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bits, bm, bn), lambda i, j, k: (0, i, j)),
            pl.BlockSpec((bits, bm, bn), lambda i, j, k: (0, i, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xq, wq, rnd_ord, rnd_imp, imp_mask.reshape(1, N))
