"""Pure-jnp oracle for the bit-flip fault-injection kernel.

Deterministic given the random planes, so kernel vs oracle tests are exact.
"""
from __future__ import annotations

import jax.numpy as jnp


def inject_ref(x, rnd, protect, ber: float, bits: int = 8):
    """x: (M,N) int32 values `bits` wide; rnd: (bits,M,N) uint32 planes;
    protect: (N,) int32 protected high-bit count per output channel."""
    thresh = jnp.uint32(min(int(ber * (1 << 32)), (1 << 32) - 1))
    mask_all = (1 << bits) - 1
    ux = x.astype(jnp.int32) & mask_all
    flips = jnp.zeros_like(ux)
    for b in range(bits):
        flip = rnd[b] < thresh
        unprotected = b < (bits - protect)[None, :]
        flips = flips | jnp.where(flip & unprotected, 1 << b, 0)
    ux = ux ^ flips
    sign = 1 << (bits - 1)
    return jnp.where((ux & sign) != 0, ux - (1 << bits), ux)
