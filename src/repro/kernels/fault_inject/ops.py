"""jit'd wrapper: random-plane generation + the fault-injection kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fault_inject.kernel import fault_inject


def random_planes(key, shape, bits: int = 8):
    return jax.random.bits(key, (bits,) + tuple(shape), jnp.uint32)


@partial(jax.jit, static_argnames=("ber", "bits", "interpret"))
def inject(key, x, protect, ber: float, bits: int = 8,
           interpret: bool = True):
    """Inject faults into int8-window values x (M,N) at BER `ber`."""
    rnd = random_planes(key, x.shape, bits)
    return fault_inject(x, rnd, protect, ber, bits, interpret=interpret)
