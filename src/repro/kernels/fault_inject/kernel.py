"""Pallas TPU kernel: BER bit-flip injection with per-channel bit protection.

Models the DLA substrate's soft errors on quantized neuron outputs: each of
the low `bits` bits flips with probability `ber`, except the top
`protect[col]` bits which are TMR-voted (immune; the O(ber^2) residual is
modelled at the simulation layer, see repro.core.faults.residual_ber).

Randomness arrives as uint32 planes (generated with jax.random in ops.py) so
the kernel is deterministic and bit-exactly testable against ref.py; on a
real TPU deployment the planes can be replaced by pltpu.prng_random_bits
in-kernel (not available in CPU interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(x_ref, rnd_ref, prot_ref, o_ref, *, ber: float, bits: int):
    thresh = jnp.uint32(min(int(ber * (1 << 32)), (1 << 32) - 1))
    mask_all = (1 << bits) - 1
    ux = x_ref[...] & mask_all
    prot = prot_ref[...]                       # (1, bn) int32
    flips = jnp.zeros_like(ux)
    for b in range(bits):
        flip = rnd_ref[b] < thresh
        unprot = b < (bits - prot)             # broadcast (1, bn)
        flips = flips | jnp.where(flip & unprot, 1 << b, 0)
    ux = ux ^ flips
    sign = 1 << (bits - 1)
    o_ref[...] = jnp.where((ux & sign) != 0, ux - (1 << bits), ux)


@functools.partial(jax.jit, static_argnames=("ber", "bits", "bm", "bn",
                                             "interpret"))
def fault_inject(x, rnd, protect, ber: float, bits: int = 8,
                 bm: int = 256, bn: int = 128, interpret: bool = True):
    """x: (M,N) int32; rnd: (bits,M,N) uint32; protect: (N,) int32."""
    M, N = x.shape
    bm, bn = min(bm, M), min(bn, N)
    assert M % bm == 0 and N % bn == 0
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        functools.partial(_kernel, ber=ber, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bits, bm, bn), lambda i, j: (0, i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, rnd, protect.reshape(1, N))
