"""Serving launcher: batched generation with the production mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b [--smoke] \
      [--batch 8] [--prompt-len 32] [--new 32] [--loop scan|python] \
      [--policy crt3 --ber 1e-4]
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--loop", choices=("scan", "python"), default="scan",
                    help="fused lax.scan decode loop (default) or the "
                         "per-token dispatch loop")
    ap.add_argument("--policy", default=None,
                    help="repro.ft registry policy name (e.g. crt3, cl)")
    ap.add_argument("--ber", type=float, default=1e-4)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_run_config
    from repro.launch.mesh import make_local_mesh, make_production_mesh
    from repro.models import build
    from repro.serve.engine import Engine, ServeConfig

    cfg = get_config(args.arch, reduced=args.smoke)
    model = build(cfg, get_run_config(args.arch))
    mesh = (make_local_mesh() if args.smoke
            else make_production_mesh())
    params = model.init(jax.random.PRNGKey(0))
    policy = None
    if args.policy:
        from repro import ft
        policy = ft.get_policy(args.policy, ber=args.ber)
    engine = Engine(model, params, mesh=None if args.smoke else mesh,
                    cfg=ServeConfig(max_new_tokens=args.new,
                                    temperature=args.temperature,
                                    loop=args.loop),
                    policy=policy)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.zeros(
            (args.batch, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec:
        batch["frames"] = jnp.zeros(
            (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16)
    out = engine.generate(batch)
    print(f"generated {out.shape[1]} tokens for {out.shape[0]} requests "
          f"in {engine.stats.roundtrips} host roundtrips ({args.loop} loop)")
    print(out)


if __name__ == "__main__":
    main()
