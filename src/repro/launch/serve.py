"""Serving launcher: batched generation with the production mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b [--smoke] \
      [--batch 8] [--prompt-len 32] [--new 32]
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_run_config
    from repro.launch.mesh import make_local_mesh, make_production_mesh
    from repro.models import build
    from repro.serve.engine import Engine, ServeConfig

    cfg = get_config(args.arch, reduced=args.smoke)
    model = build(cfg, get_run_config(args.arch))
    mesh = (make_local_mesh() if args.smoke
            else make_production_mesh())
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, mesh=None if args.smoke else mesh,
                    cfg=ServeConfig(max_new_tokens=args.new,
                                    temperature=args.temperature))
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.zeros(
            (args.batch, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec:
        batch["frames"] = jnp.zeros(
            (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16)
    out = engine.generate(batch)
    print(f"generated {out.shape[1]} tokens for {out.shape[0]} requests")
    print(out)


if __name__ == "__main__":
    main()
