"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-27b \
      --shape train_4k [--steps N] [--ckpt DIR] [--smoke]

On a real TPU fleet this process runs per host (jax.distributed.initialize
picks up the cluster env); --smoke runs the reduced config on CPU.  The mesh
is (data, model) per pod, with 'pod' prepended under --multi-pod.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config, tiny shape, local mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() (TPU fleet)")
    args = ap.parse_args()

    import jax
    if args.distributed:
        jax.distributed.initialize()

    from repro.configs import SHAPES, get_config, get_run_config
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import make_local_mesh, make_production_mesh
    from repro.models import build
    from repro.optim import AdamWConfig
    from repro.train import Trainer, TrainerConfig

    cfg = get_config(args.arch, reduced=args.smoke)
    run = get_run_config(args.arch)
    model = build(cfg, run)
    if args.smoke:
        mesh = make_local_mesh() if jax.device_count() == 1 else None
        shape = ShapeConfig("smoke", "train", 64, 8)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        shape = SHAPES[args.shape]

    tc = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                       ckpt_every=max(args.steps // 4, 1), log_every=10)
    trainer = Trainer(model, shape, AdamWConfig(dtype=run.adam_dtype),
                      tc, mesh=mesh)
    state, step = trainer.run()
    print(f"finished at step {step}; stragglers: {trainer.straggler_events}")


if __name__ == "__main__":
    main()
