"""Production mesh builders.

Functions, not module-level constants, so importing never touches jax device
state (device count is locked at first jax init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1x1 mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
