"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell, lower + compile the appropriate
step (train_step / prefill / decode) for the production meshes and record:
  - memory_analysis (per-device bytes: proves it fits a 16 GB v5e)
  - cost_analysis (HLO flops/bytes; NB scan bodies are counted once — the
    roofline uses analytic FLOPs as primary, see benchmarks/roofline.py)
  - per-collective wire bytes parsed from the post-SPMD HLO, with while-loop
    bodies multiplied by their trip counts (nested scans handled).

Results land incrementally in dryrun_results/<mesh>/<arch>__<shape>.json.

Usage:
  python -m repro.launch.dryrun [--arch A] [--shape S] [--mesh single|multi|both]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, SHAPES, get_config, get_run_config  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import build  # noqa: E402
from repro.optim import AdamWConfig  # noqa: E402
from repro.parallel import sharding as S  # noqa: E402
from repro.train.train_step import (init_state, make_decode_step,  # noqa: E402
                                    make_prefill_step, make_train_step,
                                    state_shardings)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../dryrun_results")


# --------------------------------------------------------------- HLO parse -
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s32|s64|s16|s8|u32|u64|u16|u8|pred)\[([0-9,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "s64": 8,
          "s16": 2, "s8": 1, "u32": 4, "u64": 8, "u16": 2, "u8": 1, "pred": 1}
_COLL_RE = re.compile(
    r"=\s*(\([^=]*?\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES.get(dt.split("e")[0] if dt.startswith("f8") else dt, 2)
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 1


def wire_bytes(line: str) -> float:
    """Per-device wire traffic of one collective (ring algorithms).
    XLA:CPU promotes bf16 reductions to f32 ('..._promoted' reducers); those
    move half the bytes on a TPU, where bf16 collectives are native."""
    m = _COLL_RE.search(line)
    out_bytes = _shape_bytes(m.group(1))
    if "_promoted" in line:
        out_bytes //= 2
    op = m.group(2)
    g = max(_group_size(line), 1)
    if op == "all-gather":
        return out_bytes * (g - 1) / g
    if op == "reduce-scatter":
        return out_bytes * (g - 1)
    if op == "all-reduce":
        return 2 * out_bytes * (g - 1) / g
    if op == "all-to-all":
        return out_bytes * (g - 1) / g
    return out_bytes  # collective-permute


def parse_collectives(hlo: str) -> dict:
    """Total per-device collective wire bytes, scan bodies x trip count."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"(ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->.*\{", stripped)
        if m and (stripped.endswith("{")):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                comps["__entry__"] = comps[cur]
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)

    # map body computation -> trip count.  XLA stamps the while op with
    # backend_config known_trip_count; fall back to the condition's largest
    # compare constant.
    body_trip: dict[str, int] = {}
    for name, lines in comps.items():
        for ln in lines:
            if " while(" not in ln:
                continue
            bm = re.search(r"body=%?([\w\.\-]+)", ln)
            if not bm:
                continue
            tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ln)
            if tm:
                body_trip[bm.group(1)] = int(tm.group(1))
                continue
            cm = re.search(r"condition=%?([\w\.\-]+)", ln)
            consts = [int(c) for c in re.findall(
                r"constant\((\d+)\)",
                "\n".join(comps.get(cm.group(1), [])))] if cm else []
            body_trip[bm.group(1)] = max(consts) if consts else 1

    per_op: dict[str, float] = {}
    memo: dict[str, tuple[float, dict]] = {}

    def total(comp: str, seen=()) -> tuple[float, dict]:
        if comp in memo:
            return memo[comp]
        if comp in seen or comp not in comps:
            return 0.0, {}
        t = 0.0
        ops: dict[str, float] = {}
        for ln in comps[comp]:
            cm = _COLL_RE.search(ln)
            if cm and "-done" not in ln.split("=")[1][:60]:
                b = wire_bytes(ln)
                t += b
                ops[cm.group(2)] = ops.get(cm.group(2), 0.0) + b
            if " while(" in ln:
                bm = re.search(r"body=%?([\w\.\-]+)", ln)
                if bm:
                    sub, sub_ops = total(bm.group(1), seen + (comp,))
                    trip = body_trip.get(bm.group(1), 1)
                    t += trip * sub
                    for k, v in sub_ops.items():
                        ops[k] = ops.get(k, 0.0) + trip * v
        memo[comp] = (t, ops)
        return t, ops

    entry = "__entry__" if "__entry__" in comps else next(iter(comps), None)
    t, ops = total(entry) if entry else (0.0, {})
    per_op.update(ops)
    return {"total_wire_bytes": t, "by_op": per_op,
            "trip_counts": body_trip}


# ----------------------------------------------------------------- lower ---
def _with_shardings(spec_tree, sharding_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        spec_tree, sharding_tree)


def lower_cell(arch: str, shape_name: str, mesh, ft_emu: str = "",
               serve_replicated: bool = False):
    """Lower + compile one cell on `mesh`.  Returns result dict.

    Hillclimb knobs: ft_emu lowers the FlexHyCA-protected train step
    ("two_pass" naive port vs "fused" epilogue); serve_replicated uses the
    TP-only serving weight layout (no per-step FSDP collectives)."""
    import dataclasses
    cfg = get_config(arch)
    run = get_run_config(arch)
    if ft_emu:
        run = dataclasses.replace(run, ft_emu=ft_emu)
    shape = SHAPES[shape_name]
    if not cfg.supports(shape):
        return {"skipped": True,
                "reason": "long_500k requires sub-quadratic attention"}
    model = build(cfg, run)
    opt_cfg = AdamWConfig(dtype=run.adam_dtype)

    t0 = time.time()
    if shape.kind == "train":
        step, _ = make_train_step(model, opt_cfg, mesh=mesh)
        state_spec = jax.eval_shape(
            lambda k: init_state(model, k, opt_cfg), jax.random.PRNGKey(0))
        st = _with_shardings(state_spec, state_shardings(state_spec, mesh))
        batch = _with_shardings(model.batch_specs(shape),
                                S.batch_shardings(model.batch_specs(shape), mesh))
        lowered = jax.jit(step, donate_argnums=(0,)).lower(st, batch)
    elif shape.kind == "prefill":
        pf = make_prefill_step(model, mesh=mesh)
        param_spec = model.param_specs()
        ps = _with_shardings(param_spec, S.param_shardings(param_spec, mesh))
        batch = _with_shardings(model.batch_specs(shape),
                                S.batch_shardings(model.batch_specs(shape), mesh))
        lowered = jax.jit(pf).lower(ps, batch)
    else:  # decode
        dec = make_decode_step(model, mesh=mesh)
        param_spec = model.param_specs()
        ps = _with_shardings(param_spec,
                             S.param_shardings(param_spec, mesh,
                                               no_fsdp=serve_replicated))
        cache_spec = model.cache_specs(shape.global_batch, shape.seq_len)
        cs = _with_shardings(cache_spec,
                             S.cache_shardings(cache_spec, mesh,
                                               unrolled=cfg.unroll))
        tok = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = jax.jit(dec, donate_argnums=(1,)).lower(ps, cs, tok, pos)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    # exact per-device bytes of the step's persistent inputs (state/params/
    # caches), from the sharded specs — independent of CPU-backend quirks
    def _sharded_bytes(tree):
        tot = 0
        for leaf in jax.tree.leaves(tree):
            shard = leaf.sharding.shard_shape(leaf.shape)
            n = 1
            for d in shard:
                n *= d
            tot += n * leaf.dtype.itemsize
        return tot
    if shape.kind == "train":
        persistent = _sharded_bytes(st) + _sharded_bytes(batch)
    elif shape.kind == "prefill":
        persistent = _sharded_bytes(ps) + _sharded_bytes(batch)
    else:
        persistent = _sharded_bytes(ps) + _sharded_bytes(cs)

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(zip(mesh.axis_names, (int(s) for s in mesh.devices.shape))),
        "n_devices": int(mesh.size),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost": {k: v for k, v in cost.items()
                 if isinstance(v, (int, float)) and (
                     "flops" in k or "bytes" in k or k in ("transcendentals",))},
        "collectives": coll,
        "hlo_bytes": len(hlo),
    }
    # per-device fit check (v5e: 16 GiB).  XLA:CPU's FloatNormalization pass
    # upcasts every bf16 op to f32 (no native bf16 on this host backend), so
    # measured temp is ~2x the TPU value for bf16-activation models — we
    # report the raw CPU number and a bf16-adjusted TPU estimate (verified
    # against the buffer assignment: the dominant temps are f32 copies of
    # by-design-bf16 activations).  See EXPERIMENTS.md §Dry-run.
    arg = result["memory"]["argument_bytes"] or 0
    out = result["memory"]["output_bytes"] or 0
    tmp = result["memory"]["temp_bytes"] or 0
    alias = result["memory"]["alias_bytes"] or 0
    result["memory"]["per_device_total_cpu"] = arg + out + tmp - alias
    result["memory"]["persistent_bytes"] = persistent
    tpu_total = persistent + tmp // 2
    result["memory"]["per_device_total_tpu_est"] = tpu_total
    result["memory"]["fits_16g_cpu_raw"] = bool(arg + out + tmp - alias
                                                < 16 * 1024 ** 3)
    result["memory"]["fits_16g"] = bool(tpu_total < 16 * 1024 ** 3)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--tp", type=int, default=0,
                    help="override: logical mesh (256//tp, tp) on one pod")
    ap.add_argument("--ft", default="", choices=["", "two_pass", "fused"])
    ap.add_argument("--serve-replicated", action="store_true")
    ap.add_argument("--tag", default="",
                    help="results subdir tag for hillclimb variants")
    args = ap.parse_args()

    meshes = []
    if args.tp:
        import jax as _jax
        meshes.append((f"single_tp{args.tp}",
                       _jax.make_mesh((256 // args.tp, args.tp),
                                      ("data", "model"))))
    else:
        if args.mesh in ("single", "both"):
            meshes.append(("single", make_production_mesh(multi_pod=False)))
        if args.mesh in ("multi", "both"):
            meshes.append(("multi", make_production_mesh(multi_pod=True)))

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)

    n_ok = n_skip = n_fail = 0
    for mesh_name, mesh in meshes:
        outdir = os.path.join(args.out, mesh_name + args.tag)
        os.makedirs(outdir, exist_ok=True)
        for arch in archs:
            for shape in shapes:
                path = os.path.join(outdir, f"{arch}__{shape}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {mesh_name} {arch} {shape}")
                    continue
                print(f"[lower ] {mesh_name} {arch} {shape} ...", flush=True)
                try:
                    res = lower_cell(arch, shape, mesh, ft_emu=args.ft,
                                     serve_replicated=args.serve_replicated)
                    if res.get("skipped"):
                        n_skip += 1
                        print(f"[skip  ] {arch} {shape}: {res['reason']}")
                    else:
                        n_ok += 1
                        mm = res["memory"]
                        print(f"[ok    ] {arch} {shape} "
                              f"compile={res['compile_s']}s "
                              f"mem/dev={mm['per_device_total_tpu_est']/2**30:.2f}GiB"
                              f"(cpu raw {mm['per_device_total_cpu']/2**30:.2f}) "
                              f"fits={mm['fits_16g']} "
                              f"coll={res['collectives']['total_wire_bytes']/2**30:.2f}GiB",
                              flush=True)
                except Exception:
                    n_fail += 1
                    res = {"arch": arch, "shape": shape, "failed": True,
                           "error": traceback.format_exc()}
                    print(f"[FAIL  ] {arch} {shape}\n{res['error']}",
                          flush=True)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
    print(f"done: ok={n_ok} skip={n_skip} fail={n_fail}")
    return n_fail


if __name__ == "__main__":
    raise SystemExit(main())
