"""Gradient compression for the DP reduce path (beyond-paper distributed
optimization): int8 quantization with per-shard scales and error feedback.

``compressed_psum`` runs inside shard_map over the DP axes: each shard
quantizes its local gradient to int8 + one f32 scale, the psum moves 4x less
gradient payload, and the error-feedback state carries the quantization
residual into the next step so the optimizer sees an unbiased long-run
gradient.  ``ef`` state shards exactly like the gradient.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.compat import shard_map


def quantize_grad(g, ef=None):
    """int8-quantize g (+error feedback).  Returns (q, scale, new_ef)."""
    if ef is not None:
        g = g + ef
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_ef = g - deq
    return q, scale, new_ef


def compressed_psum(g, axis, ef=None):
    """int8-compressed all-reduce of g over `axis` (inside shard_map)."""
    q, scale, new_ef = quantize_grad(g, ef)
    # payload: int8 tensor + f32 scalar — 4x less wire than f32 psum
    total = jax.lax.psum(q.astype(jnp.float32) * scale, axis)
    n = jax.lax.psum(jnp.ones(()), axis)
    return total / n, new_ef


def compressed_psum_test(key, n_dev: int = 8) -> float:
    """Relative error of one compressed mean-reduce vs exact (test helper)."""
    mesh = jax.make_mesh((n_dev,), ("d",))
    g = jax.random.normal(key, (n_dev, 64, 64))

    def shard_fn(gl):
        out, _ = compressed_psum(gl[0], "d")
        return out[None]

    out = jax.jit(shard_map(shard_fn, mesh=mesh, in_specs=P("d"),
                            out_specs=P("d")))(g)
    exact = g.mean(0)
    err = float(jnp.linalg.norm(out[0] - exact) / jnp.linalg.norm(exact))
    return err
