"""Parameter / batch / cache PartitionSpec rules.

Every weight is sharded 2-D: the tensor-parallel dim over 'model' and an FSDP
dim over the data axes (('pod','data') on the multi-pod mesh).  Dims that do
not divide the axis size are left unsharded (replicated) — e.g. seamless'
vocab 256206 on a 16-way axis.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey

from repro.parallel.ctx import MeshCtx


def make_ctx(mesh: Mesh) -> MeshCtx:
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return MeshCtx(mesh=mesh, dp=dp, tp="model")


def _axsize(mesh, axes) -> int:
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(__import__("math").prod(mesh.shape[a] for a in axes))


def _maybe(mesh, dim: int, axes):
    """Shard `dim` over `axes` only when it divides evenly."""
    if axes is None or dim % _axsize(mesh, axes) != 0:
        return None
    return axes if isinstance(axes, str) else tuple(axes)


# rule tables: name -> (spec builder over unstacked dims)
_IN_PROJ = {"wq", "wk", "wv", "wi", "wg", "in_proj", "w_x", "w_gate"}
_OUT_PROJ = {"wo", "out_proj", "w_out"}
_SQUARE = {"w_a", "w_i"}


def param_spec(path, leaf, mesh: Mesh) -> P:
    names = [k.key for k in path if isinstance(k, DictKey)]
    name = names[-1]
    stacked = names[0].startswith("seg") or names[0] == "enc_blocks"
    fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = "model"
    shape = leaf.shape[1:] if stacked else leaf.shape
    nd = len(shape)

    def spec(*entries):
        entries = list(entries) + [None] * (nd - len(entries))
        if stacked:
            entries = [None] + entries
        return P(*entries)

    if name in ("embed", "unembed"):
        return spec(_maybe(mesh, shape[0], tp), _maybe(mesh, shape[1], fsdp))
    if name in _IN_PROJ and nd == 2:
        return spec(_maybe(mesh, shape[0], fsdp), _maybe(mesh, shape[1], tp))
    if name in _IN_PROJ and nd == 3:     # MoE experts (E, D, F)
        return spec(_maybe(mesh, shape[0], tp), _maybe(mesh, shape[1], fsdp))
    if name in _OUT_PROJ and nd == 2:
        return spec(_maybe(mesh, shape[0], tp), _maybe(mesh, shape[1], fsdp))
    if name in _OUT_PROJ and nd == 3:    # MoE experts (E, F, D)
        return spec(_maybe(mesh, shape[0], tp), _maybe(mesh, shape[1], fsdp))
    if name in _SQUARE:   # block-diagonal RG-LRU gates (heads, bw, bw)
        return spec(_maybe(mesh, shape[0], tp), None,
                    _maybe(mesh, shape[2], fsdp) if nd > 2 else None)
    if name == "conv_w":
        return spec(None, _maybe(mesh, shape[1], tp))
    return spec()  # norms, biases, scalars: replicated


def param_shardings(param_tree, mesh: Mesh, no_fsdp: bool = False):
    """no_fsdp: serving layout — weights sharded over 'model' only and
    replicated over the DP axes (kills the per-step FSDP/partial-sum
    collectives when the TP-sharded copy fits HBM)."""
    fsdp_names = {a for a in ("pod", "data") if a in mesh.axis_names}

    def _clean(e):
        if e is None:
            return None
        if isinstance(e, tuple):
            return None if set(e) & fsdp_names else e
        return None if e in fsdp_names else e

    def one(p, x):
        spec = param_spec(p, x, mesh)
        if no_fsdp:
            spec = P(*[_clean(e) for e in spec])
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, param_tree)


def batch_shardings(batch_tree, mesh: Mesh):
    """Batch dim over the DP axes (replicated if it doesn't divide)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def one(x):
        entry = _maybe(mesh, x.shape[0], dp)
        return NamedSharding(mesh, P(*([entry] + [None] * (x.ndim - 1))))
    return jax.tree.map(one, batch_tree)


def cache_shardings(cache_tree, mesh: Mesh, unrolled: bool = False):
    """KV/state caches: batch over DP, head/width dims over 'model' when they
    divide.  Cache layouts (leading 'blocks' stack dim unless unrolled):
      attn k/v: (B, C, KH, Dh); rglru h: (B, W), conv: (B, K-1, W);
      ssd state: (B, H, P, N), conv: (B, K-1, C).

    Paged attention caches (a ``bt`` block table beside ``k``/``v``) store a
    *pool* ``(n_blocks, block_size, KH, Dh)``: block tables hold **global**
    block ids, so the pool dim (and the block dim) must stay replicated over
    the DP axes — sharding dim 0 as if it were batch would break every
    table lookup.  Pools shard on kv heads over 'model' only (no split-K
    fallback: the in-block dim is ``block_size``, not cache length); the
    table itself is per-slot state and shards with the batch."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # paged pool detection: any cache dict holding a block table holds pools
    leaves = jax.tree_util.tree_flatten_with_path(cache_tree)[0]
    pooled = {tuple(k.key for k in p[:-1] if isinstance(k, DictKey))
              for p, _ in leaves
              if isinstance(p[-1], DictKey) and p[-1].key == "bt"}

    def one(path, x):
        names = [k.key for k in path if isinstance(k, DictKey)]
        stacked = (not unrolled) and names[0].startswith("seg")
        shape = x.shape[1:] if stacked else x.shape
        name = names[-1]
        paged = tuple(names[:-1]) in pooled
        if paged and name in ("k", "v"):
            # (n_blocks, block_size, KH, Dh): pool + block dims replicated
            entries = [None] * len(shape)
            if len(shape) == 4:
                entries[2] = _maybe(mesh, shape[2], "model")
        else:
            entries = [_maybe(mesh, shape[0], dp)] + [None] * (len(shape) - 1)
            if not paged and name in ("k", "v", "ck", "cv") and len(shape) == 4:
                # (B, C, KH, Dh): prefer sharding kv heads; for archs whose
                # few kv heads don't divide the TP axis, shard the cache
                # length instead (flash-decoding split-K: per-shard partial
                # softmax + tiny psums) so the cache is never TP-replicated.
                if _maybe(mesh, shape[2], "model"):
                    entries[2] = "model"
                else:
                    entries[1] = _maybe(mesh, shape[1], "model")
            elif name == "state" and len(shape) == 4:
                entries[1] = _maybe(mesh, shape[1], "model")
            elif name in ("h",) and len(shape) == 2:
                entries[1] = _maybe(mesh, shape[1], "model")
            elif name == "conv" and len(shape) == 3:
                entries[2] = _maybe(mesh, shape[2], "model")
        if stacked:
            entries = [None] + entries
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(one, cache_tree)
