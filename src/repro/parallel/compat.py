"""jax-version compatibility for the parallel stack.

``shard_map`` moved twice across the supported jax range: 0.4.x ships it at
``jax.experimental.shard_map.shard_map`` with the replication check spelled
``check_rep``; newer releases promote it to ``jax.shard_map`` and rename the
knob ``check_vma``.  ``shard_map`` here resolves the import once and maps the
single ``check`` kwarg onto whichever spelling the installed jax takes (the
same style of gate as the AbstractMesh shim in tests/test_sharding_rules.py).
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):                    # jax >= 0.6
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:                                            # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = True):
    """Version-portable ``shard_map`` (``check`` = check_rep / check_vma)."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check})
