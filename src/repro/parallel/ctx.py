"""Mesh context: logical-axis resolution for model code.

Model code never names physical mesh axes; it uses logical names:
  "dp"  — batch/data-parallel axes (('pod','data') multi-pod, ('data',) else)
  "tp"  — tensor-parallel axis ('model')
  "fsdp"— weight-sharding axes (== dp axes)
A context object resolves them; when no context is set (plain CPU tests) the
constraints become no-ops and MoE runs its single-shard path on a 1x1 mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    mesh: Mesh
    dp: tuple[str, ...] = ("data",)
    tp: str = "model"

    @property
    def dp_size(self) -> int:
        return int(__import__("math").prod(self.mesh.shape[a] for a in self.dp))

    @property
    def tp_size(self) -> int:
        return int(self.mesh.shape[self.tp])

    def resolve(self, *logical) -> P:
        """Map logical axis names to a PartitionSpec."""
        out = []
        for ax in logical:
            if ax is None:
                out.append(None)
            elif ax == "dp":
                out.append(self.dp if len(self.dp) > 1 else self.dp[0])
            elif ax == "tp":
                out.append(self.tp)
            else:
                raise ValueError(f"unknown logical axis {ax!r}")
        return P(*out)

    def sharding(self, *logical) -> NamedSharding:
        return NamedSharding(self.mesh, self.resolve(*logical))


_CTX: list[MeshCtx | None] = [None]


def get_ctx() -> MeshCtx | None:
    return _CTX[0]


def set_ctx(ctx: MeshCtx | None):
    _CTX[0] = ctx


@contextlib.contextmanager
def mesh_ctx(ctx: MeshCtx | None):
    prev = _CTX[0]
    _CTX[0] = ctx
    try:
        yield ctx
    finally:
        _CTX[0] = prev


def ac(x: jax.Array, *logical):
    """Activation sharding constraint (no-op without a mesh context), with
    divisibility fallback: a dim that doesn't divide is left unsharded."""
    ctx = get_ctx()
    if ctx is None:
        return x
    spec = []
    for dim, ax in enumerate(logical):
        if ax is None:
            spec.append(None)
            continue
        size = ctx.dp_size if ax == "dp" else ctx.tp_size
        if x.shape[dim] % size == 0:
            spec.append(ctx.resolve(ax)[0])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))
