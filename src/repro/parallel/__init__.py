from repro.parallel.ctx import MeshCtx, ac, get_ctx, mesh_ctx, set_ctx  # noqa: F401
