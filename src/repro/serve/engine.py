"""Batched serving engine: prefill + scan-fused greedy/temperature decode.

The decode loop is a single ``jax.lax.scan`` executable: the per-step
fault-draw keys are folded *inside* the scan from the step index, the
sampling key is threaded through the carry, and the caches are donated once
at the loop boundary — so a whole generation costs two host dispatches
(prefill + loop) instead of one per token.  ``Engine(loop="python")`` keeps
the legacy per-token dispatch path; at temperature 0 the two paths emit
bit-identical tokens (tests/test_serve_engine.py proves it under every
registry protection policy and both ft backends).

Works on any mesh: passing ``mesh=`` device_puts the params in the serving
layout (``param_shardings(no_fsdp=True)``: TP over 'model', replicated over
the DP axes), shards the input batch over DP, and constrains the caches the
prefill returns — batch-sharded over DP and head-sharded over 'model' (paged
pools stay DP-replicated; see parallel.sharding.cache_shardings).

Fault-tolerant serving: pass a ``repro.ft`` protection policy (object or
registry name) and every projection of prefill and decode computes through
the faulty-DLA path with that policy's protection — the serving-side view of
the paper's cross-layer stack.

For continuous-batching request scheduling on top of this engine, see
``repro.serve.scheduler``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.parallel import sharding as S
from repro.parallel.ctx import mesh_ctx

LOOPS = ("scan", "python")


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0
    seed: int = 0
    loop: str = "scan"            # "scan" (fused) | "python" (per-token)


@dataclasses.dataclass
class ServeStats:
    """Host-dispatch accounting for the last ``generate`` call.

    ``roundtrips`` counts jitted executable invocations (one host->device
    dispatch + result sync each): the python loop pays 1 prefill + 1 per
    token; the scan loop pays 1 prefill + 1 for the whole generation.
    """
    roundtrips: int = 0
    tokens: int = 0


class Engine:
    def __init__(self, model, params, mesh=None, cfg: ServeConfig | None = None,
                 policy=None, ft_backend: str = "reference", ft_t=None,
                 ft_interpret: bool = True, loop: str | None = None):
        """`policy`: a repro.ft ProtectionPolicy (or registry name) applied to
        every projection.  For ft_backend="pallas" under the jitted serve
        loop, `ft_t` must carry the calibrated truncation LSB(s) — one int or
        a per-site {name: int} table — and ft_interpret=False runs the
        compiled kernel on TPU.  `loop` overrides cfg.loop."""
        from repro.ft import as_policy
        self.model, self.params = model, params
        self.mesh = mesh
        self.cfg = cfg or ServeConfig()
        self.loop = loop or self.cfg.loop
        if self.loop not in LOOPS:
            raise ValueError(f"unknown loop {self.loop!r}; expected {LOOPS}")
        self.policy = as_policy(policy)
        self.ft_backend = ft_backend
        self.ft_t = ft_t
        self.ft_interpret = ft_interpret
        self.stats = ServeStats()
        self._n_calls = 0
        ctx = S.make_ctx(mesh) if mesh is not None else None
        if mesh is not None:
            # serving layout: TP-sharded weights, replicated over DP (the
            # docstring's claim, applied for real at construction)
            self.params = jax.device_put(
                params, S.param_shardings(params, mesh, no_fsdp=True))

        def _shard_caches(caches):
            if mesh is None or caches is None:
                return caches
            return jax.lax.with_sharding_constraint(
                caches, S.cache_shardings(caches, mesh))

        def _ftc(ftkey):
            if self.policy is None:
                return None
            from repro.models.common import FTCtx
            return FTCtx(self.policy, ftkey, backend=self.ft_backend,
                         t=self.ft_t, interpret=self.ft_interpret)

        temperature = self.cfg.temperature

        def _sample(logits, key):
            if temperature <= 0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jax.random.categorical(
                key, logits / temperature, axis=-1).astype(jnp.int32)

        def _prefill(params, batch, max_len, ftkey):
            with mesh_ctx(ctx):
                caches, logits = model.prefill(params, batch, max_len=max_len,
                                               ftc=_ftc(ftkey))
                return _shard_caches(caches), logits

        def _decode(params, caches, token, pos, ftkey):
            with mesh_ctx(ctx):
                return model.decode_step(params, caches, token, pos,
                                         ftc=_ftc(ftkey))

        def _decode_loop(params, caches, tok0, pos0, ftkey, skey, n_new):
            # One executable for the whole generation.  Step i consumes the
            # carried token, decodes it at position pos0+i with the fault
            # stream fold_in(ftkey, i+1) (matching the python loop), folds i
            # into the sampling key, and emits the consumed token — so ys is
            # [tok0, tok1, ..., tok_{n_new-1}], identical to the python path.
            with mesh_ctx(ctx):
                def body(carry, i):
                    caches, tok, key = carry
                    caches, logits = model.decode_step(
                        params, caches, tok, pos0 + i,
                        ftc=_ftc(jax.random.fold_in(ftkey, i + 1)))
                    key = jax.random.fold_in(key, i)
                    nxt = _sample(logits, key)
                    return (caches, nxt, key), tok

                (caches, _, _), toks = jax.lax.scan(
                    body, (caches, tok0, skey),
                    jnp.arange(n_new, dtype=jnp.int32))
            # the final caches are dead to the caller (one generation per
            # loop) but MUST be returned anyway: donated buffers only alias
            # when they line up with an output, so dropping them here turns
            # donate_argnums=(1,) into a silent full-cache copy every call
            # (tools/ftverify FTV105 checks the lowered HLO for this)
            return caches, jnp.moveaxis(toks, 0, 1)  # (B, n_new)

        self._sample = _sample
        self._prefill = jax.jit(_prefill, static_argnums=(2,))
        self._decode = jax.jit(_decode, donate_argnums=(1,))
        self._loop = jax.jit(_decode_loop, static_argnums=(6,),
                             donate_argnums=(1,))

    # ------------------------------------------------------------ keys -----
    def _call_key(self, key, seed):
        """Per-call base key.  By default the engine folds the call index
        into the config seed so back-to-back ``generate()`` calls draw fresh
        fault patterns and fresh temperature samples; ``key=``/``seed=``
        pins a call explicitly (replayable reliability accounting)."""
        if key is not None and seed is not None:
            raise ValueError("pass at most one of key= / seed=")
        if key is None:
            key = jax.random.PRNGKey(self.cfg.seed if seed is None else seed)
            if seed is None:
                key = jax.random.fold_in(key, self._n_calls)
        self._n_calls += 1
        ftkey, skey = jax.random.split(jnp.asarray(key))
        return ftkey, skey

    # -------------------------------------------------------- generation ---
    def generate(self, batch, max_new_tokens: int | None = None, *,
                 key=None, seed: int | None = None):
        """batch: model input dict (prompts).  Returns (B, new) tokens.

        ``key``/``seed`` pin this call's fault-draw and sampling streams;
        without them each call folds its index into ``cfg.seed`` (two calls
        never replay the same faults)."""
        n_new = (self.cfg.max_new_tokens if max_new_tokens is None
                 else max_new_tokens)
        prompt_len = batch["tokens"].shape[1]
        if self.model.cfg.frontend == "vision":
            prompt_len += self.model.cfg.n_frontend_tokens
        max_len = prompt_len + n_new
        if self.mesh is not None:
            batch = jax.device_put(batch, S.batch_shardings(batch, self.mesh))
        ftkey, skey = self._call_key(key, seed)
        caches, logits = self._prefill(self.params, batch, max_len, ftkey)
        tok = self._sample(logits, skey)
        if n_new == 0:                       # prefill-only probe
            self.stats = ServeStats(roundtrips=1, tokens=0)
            return jnp.zeros((tok.shape[0], 0), jnp.int32)
        pos0 = jnp.asarray(prompt_len, jnp.int32)
        if self.loop == "scan":
            _, out = self._loop(self.params, caches, tok, pos0, ftkey, skey,
                                n_new)
            self.stats = ServeStats(roundtrips=2, tokens=int(out.size))
            return out
        out = []
        for i in range(n_new):
            out.append(tok)
            caches, logits = self._decode(
                self.params, caches, tok, pos0 + i,
                jax.random.fold_in(ftkey, i + 1))
            skey = jax.random.fold_in(skey, i)
            tok = self._sample(logits, skey)
        out = jnp.stack(out, axis=1)
        self.stats = ServeStats(roundtrips=1 + n_new, tokens=int(out.size))
        return out
