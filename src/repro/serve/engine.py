"""Batched serving engine: prefill + greedy/temperature decode loop.

The decode step donates its caches, so serving memory is a single cache
allocation regardless of generation length.  Works on any mesh: the cache is
batch-sharded over DP and head-sharded over 'model' (see parallel.sharding).

Fault-tolerant serving: pass a ``repro.ft`` protection policy (object or
registry name) and every projection of prefill and decode computes through
the faulty-DLA path with that policy's protection — the serving-side view of
the paper's cross-layer stack.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.parallel import sharding as S
from repro.parallel.ctx import mesh_ctx


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0
    seed: int = 0


class Engine:
    def __init__(self, model, params, mesh=None, cfg: ServeConfig | None = None,
                 policy=None, ft_backend: str = "reference", ft_t=None,
                 ft_interpret: bool = True):
        """`policy`: a repro.ft ProtectionPolicy (or registry name) applied to
        every projection.  For ft_backend="pallas" under the jitted serve
        loop, `ft_t` must carry the calibrated truncation LSB(s) — one int or
        a per-site {name: int} table — and ft_interpret=False runs the
        compiled kernel on TPU."""
        from repro.ft import as_policy
        self.model, self.params = model, params
        self.mesh = mesh
        self.cfg = cfg or ServeConfig()
        self.policy = as_policy(policy)
        self.ft_backend = ft_backend
        self.ft_t = ft_t
        self.ft_interpret = ft_interpret
        ctx = S.make_ctx(mesh) if mesh is not None else None

        def _ftc(ftkey):
            if self.policy is None:
                return None
            from repro.models.common import FTCtx
            return FTCtx(self.policy, ftkey, backend=self.ft_backend,
                         t=self.ft_t, interpret=self.ft_interpret)

        def _prefill(params, batch, max_len, ftkey):
            with mesh_ctx(ctx):
                return model.prefill(params, batch, max_len=max_len,
                                     ftc=_ftc(ftkey))

        def _decode(params, caches, token, pos, ftkey):
            with mesh_ctx(ctx):
                return model.decode_step(params, caches, token, pos,
                                         ftc=_ftc(ftkey))

        self._prefill = jax.jit(_prefill, static_argnums=(2,))
        self._decode = jax.jit(_decode, donate_argnums=(1,))

    def generate(self, batch, max_new_tokens: int | None = None):
        """batch: model input dict (prompts).  Returns (B, new) tokens."""
        n_new = max_new_tokens or self.cfg.max_new_tokens
        prompt_len = batch["tokens"].shape[1]
        if self.model.cfg.frontend == "vision":
            prompt_len += self.model.cfg.n_frontend_tokens
        max_len = prompt_len + n_new
        ftkey = jax.random.PRNGKey(self.cfg.seed + 7919)  # fault-draw stream
        caches, logits = self._prefill(self.params, batch, max_len, ftkey)
        key = jax.random.PRNGKey(self.cfg.seed)
        out = []
        tok = self._sample(logits, key)
        for i in range(n_new):
            out.append(tok)
            caches, logits = self._decode(
                self.params, caches, tok,
                jnp.asarray(prompt_len + i, jnp.int32),
                jax.random.fold_in(ftkey, i + 1))
            key = jax.random.fold_in(key, i)
            tok = self._sample(logits, key)
        return jnp.stack(out, axis=1)

    def _sample(self, logits, key):
        if self.cfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)
