"""Batched serving engine: prefill + greedy/temperature decode loop.

The decode step donates its caches, so serving memory is a single cache
allocation regardless of generation length.  Works on any mesh: the cache is
batch-sharded over DP and head-sharded over 'model' (see parallel.sharding).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.parallel import sharding as S
from repro.parallel.ctx import mesh_ctx


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0
    seed: int = 0


class Engine:
    def __init__(self, model, params, mesh=None, cfg: ServeConfig | None = None):
        self.model, self.params = model, params
        self.mesh = mesh
        self.cfg = cfg or ServeConfig()
        ctx = S.make_ctx(mesh) if mesh is not None else None

        def _prefill(params, batch, max_len):
            with mesh_ctx(ctx):
                return model.prefill(params, batch, max_len=max_len)

        def _decode(params, caches, token, pos):
            with mesh_ctx(ctx):
                return model.decode_step(params, caches, token, pos)

        self._prefill = jax.jit(_prefill, static_argnums=(2,))
        self._decode = jax.jit(_decode, donate_argnums=(1,))

    def generate(self, batch, max_new_tokens: int | None = None):
        """batch: model input dict (prompts).  Returns (B, new) tokens."""
        n_new = max_new_tokens or self.cfg.max_new_tokens
        prompt_len = batch["tokens"].shape[1]
        if self.model.cfg.frontend == "vision":
            prompt_len += self.model.cfg.n_frontend_tokens
        max_len = prompt_len + n_new
        caches, logits = self._prefill(self.params, batch, max_len)
        key = jax.random.PRNGKey(self.cfg.seed)
        out = []
        tok = self._sample(logits, key)
        for i in range(n_new):
            out.append(tok)
            caches, logits = self._decode(
                self.params, caches, tok,
                jnp.asarray(prompt_len + i, jnp.int32))
            key = jax.random.fold_in(key, i)
            tok = self._sample(logits, key)
        return jnp.stack(out, axis=1)

    def _sample(self, logits, key):
        if self.cfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)
