from repro.serve.engine import Engine, ServeConfig, ServeStats  # noqa: F401
from repro.serve.scheduler import (Request, SchedStats,  # noqa: F401
                                   Scheduler, SchedulerConfig)
