"""Continuous-batching request scheduler on top of the scan-fused engine.

A fixed pool of ``max_batch`` decode *slots* serves a queue of requests:

  * **admit** — a free slot prefils the next queued request (prompt padded
    up to a configured length *bucket*, so prefill compiles once per bucket,
    not once per prompt length) and its caches are written into the slot's
    row of the batched cache pytree;
  * **decode** — all slots step together through a fused ``lax.scan`` chunk
    of ``decode_chunk`` tokens (one host roundtrip per chunk, not per
    token), with *per-row* positions (every slot sits at its own depth);
  * **evict** — a request leaves its slot when it emits ``eos_id`` or hits
    its ``max_new_tokens``; the slot is immediately re-admittable.

Fault-tolerant serving keeps **per-request reliability accounting**: each
request draws its faults from its own key stream ``fold_in(base, rid)``
folded by its own token index, carried through the batch as an (B, 2) key
array (``FTCtx`` per-row mode).  Row b's fault draws — and its quantization
scales — depend only on request b, so evicting or admitting neighbours
never perturbs another request's generation (reference backend;
``policy.weight_faults`` must be False because weight SRAM is shared — the
DLA models it as ECC-protected anyway).

Exactness of bucket padding: prompts are right-padded; pad positions write
cache slots *ahead* of the request's position, which decode overwrites
token-by-token while the per-row valid mask hides the rest — bit-identical
to an unpadded prefill.  Two structural limits follow: sliding-window
layers need ``max(buckets) <= cfg.window`` (otherwise pads would evict real
history from the rolling cache), and recurrent blocks (R/S) are rejected —
their prefill state would integrate the pad tokens.  MoE models schedule
fine, but expert-capacity competition couples rows (per-request streams
stay independent; token *drops* may differ with batch composition).
"""
from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    tokens: list                     # prompt token ids
    max_new_tokens: int = 16
    extras: dict | None = None       # e.g. {"patch_embeds": (P, D)} for VLMs
    # filled by the scheduler:
    generated: list = dataclasses.field(default_factory=list)
    finish_reason: str | None = None   # "eos" | "length"


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 4               # concurrent decode slots
    buckets: tuple = (8, 16)         # prompt lengths are padded up to these
    max_new_tokens: int = 16         # per-request cap (cache headroom)
    decode_chunk: int = 4            # fused scan steps per host roundtrip
    temperature: float = 0.0
    eos_id: int = -1                 # < 0: no EOS eviction
    seed: int = 0


@dataclasses.dataclass
class SchedStats:
    prefill_calls: int = 0
    insert_calls: int = 0
    chunk_calls: int = 0
    tokens: int = 0

    @property
    def roundtrips(self) -> int:
        return self.prefill_calls + self.insert_calls + self.chunk_calls


class Scheduler:
    def __init__(self, model, params, cfg: SchedulerConfig | None = None,
                 policy=None, ft_backend: str = "reference", ft_t=None,
                 ft_interpret: bool = True):
        from repro.ft import as_policy
        self.model, self.params = model, params
        self.cfg = cfg or SchedulerConfig()
        self.policy = as_policy(policy)
        self.stats = SchedStats()

        mcfg = model.cfg
        kinds = set(T._layer_kinds(mcfg))
        if kinds & {"R", "S"} or mcfg.enc_dec:
            raise ValueError(
                "the bucketed scheduler supports attention families only: "
                "right-padded prefill would integrate pad tokens into "
                "recurrent/encoder state (use Engine for R/S and enc-dec)")
        self._front = (mcfg.n_frontend_tokens if mcfg.frontend == "vision"
                       else 0)
        if "L" in kinds and self._front + max(self.cfg.buckets) > mcfg.window:
            raise ValueError(
                f"buckets {self.cfg.buckets} (+ {self._front} frontend "
                f"tokens) exceed the sliding window {mcfg.window}: pad "
                "tokens would evict real history from the rolling cache")
        if self.policy is not None:
            if self.policy.weight_faults:
                raise ValueError(
                    "per-request fault streams need policy.weight_faults="
                    "False (weights are shared across slots); use "
                    "policy.tune(weight_faults=False)")
            if ft_backend != "reference":
                raise ValueError("per-request fault streams are reference-"
                                 "backend only")

        # cache capacity: every slot can hold the largest admitted prompt
        # plus a full generation
        self.capacity = (max(self.cfg.buckets) + self.cfg.max_new_tokens
                         + self._front)

        base = jax.random.PRNGKey(self.cfg.seed)
        ftbase, sbase = jax.random.split(base)
        self._ftbase, self._sbase = ftbase, sbase
        temperature = self.cfg.temperature
        capacity = self.capacity

        def _ftc(keys):
            if self.policy is None:
                return None
            from repro.models.common import FTCtx
            return FTCtx(self.policy, keys, backend=ft_backend, t=ft_t,
                         interpret=ft_interpret)

        def _sample(logits, keys, tsteps):
            if temperature <= 0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            def one(k, t, lg):
                return jax.random.categorical(
                    jax.random.fold_in(k, t + 1), lg / temperature)
            return jax.vmap(one)(keys, tsteps, logits).astype(jnp.int32)

        def _prefill_one(params, batch1, last_idx, rid):
            # per-request streams: prefill draws from fold(fold(base, rid), 0)
            # (B=1, so a single stream per call is already per-request)
            ftk = jax.random.fold_in(jax.random.fold_in(ftbase, rid), 0)
            caches, logits = model.prefill(params, batch1, max_len=capacity,
                                           ftc=_ftc(ftk),
                                           last_index=last_idx)
            skey = jax.random.fold_in(sbase, rid)
            tok0 = _sample(logits, skey[None], jnp.full((1,), -1, jnp.int32))
            return caches, tok0[0]

        def _insert(caches, c1, slot):
            def one(path, c, n):
                names = [getattr(k, "key", "") for k in path]
                axis = 1 if str(names[0]).startswith("seg") else 0
                return jax.lax.dynamic_update_slice_in_dim(c, n, slot, axis)
            return jax.tree_util.tree_map_with_path(one, caches, c1)

        def _chunk(params, caches, tok, pos, tstep, rids, active, n_steps):
            act = active.astype(jnp.int32)

            def body(carry, _):
                caches, tok, pos, tstep = carry
                keys = jax.vmap(
                    lambda r, t: jax.random.fold_in(
                        jax.random.fold_in(ftbase, r), t + 1))(rids, tstep)
                caches, logits = model.decode_step(params, caches, tok, pos,
                                                   ftc=_ftc(keys))
                skeys = jax.vmap(jax.random.fold_in)(
                    jnp.broadcast_to(sbase, (rids.shape[0],) + sbase.shape),
                    rids)
                nxt = _sample(logits, skeys, tstep)
                tok = jnp.where(active, nxt, tok)
                pos = pos + act
                tstep = tstep + act
                return (caches, tok, pos, tstep), nxt

            (caches, tok, pos, tstep), toks = jax.lax.scan(
                body, (caches, tok, pos, tstep), None, length=n_steps)
            return caches, tok, pos, tstep, jnp.moveaxis(toks, 0, 1)

        self._prefill_one = jax.jit(_prefill_one)
        self._insert = jax.jit(_insert, donate_argnums=(0,))
        self._chunk = jax.jit(_chunk, static_argnums=(7,),
                              donate_argnums=(1,))

    # ------------------------------------------------------------ helpers --
    def _bucket(self, n: int) -> int:
        for b in sorted(self.cfg.buckets):
            if n <= b:
                return b
        raise ValueError(f"prompt length {n} exceeds largest bucket "
                         f"{max(self.cfg.buckets)}")

    def _make_batch1(self, req: Request):
        L = len(req.tokens)
        Lb = self._bucket(L)
        toks = np.zeros((1, Lb), np.int32)
        toks[0, :L] = req.tokens
        batch1 = {"tokens": jnp.asarray(toks)}
        for k, v in (req.extras or {}).items():
            batch1[k] = jnp.asarray(v)[None]
        last_idx = jnp.asarray([self._front + L - 1], jnp.int32)
        return batch1, last_idx, self._front + L

    # ---------------------------------------------------------------- run --
    def run(self, requests) -> dict:
        """Serve `requests` to completion; returns {rid: Request} with
        ``generated`` / ``finish_reason`` filled."""
        cfg = self.cfg
        B = cfg.max_batch
        self.stats = SchedStats()
        seen_rids = set()
        for req in requests:
            self._bucket(len(req.tokens))   # fail fast, before any compute
            if req.rid in seen_rids:
                raise ValueError(
                    f"duplicate request id {req.rid}: results are keyed by "
                    "rid and the per-request fault streams derive from it")
            seen_rids.add(req.rid)
            if req.max_new_tokens > cfg.max_new_tokens:
                raise ValueError(
                    f"request {req.rid} wants {req.max_new_tokens} tokens "
                    f"but the slot capacity budgets cfg.max_new_tokens="
                    f"{cfg.max_new_tokens}: decoding past capacity would "
                    "overwrite cache history")
            req.generated = []              # a re-submitted Request restarts
            req.finish_reason = None
        queue = collections.deque(requests)
        slots: list[Request | None] = [None] * B
        out = {}

        caches = self.model.init_cache(B, self.capacity)
        tok = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        tstep = np.zeros((B,), np.int32)
        rids = np.zeros((B,), np.int32)

        def finish(s, req, reason):
            req.finish_reason = reason
            out[req.rid] = req
            slots[s] = None

        while queue or any(s is not None for s in slots):
            # ---- admit into free slots (a request that finishes at
            # prefill — EOS first token or max_new_tokens == 1 — does not
            # use up the slot's turn; the slot retries the queue) ---------
            for s in range(B):
                while slots[s] is None and queue:
                    req = queue.popleft()
                    batch1, last_idx, plen = self._make_batch1(req)
                    c1, tok0 = self._prefill_one(
                        self.params, batch1, last_idx,
                        jnp.asarray(req.rid, jnp.int32))
                    self.stats.prefill_calls += 1
                    t0 = int(tok0)
                    req.generated.append(t0)
                    self.stats.tokens += 1
                    if cfg.eos_id >= 0 and t0 == cfg.eos_id:
                        req.finish_reason = "eos"
                        out[req.rid] = req
                        continue
                    if len(req.generated) >= req.max_new_tokens:
                        req.finish_reason = "length"
                        out[req.rid] = req
                        continue
                    caches = self._insert(caches, c1,
                                          jnp.asarray(s, jnp.int32))
                    self.stats.insert_calls += 1
                    slots[s] = req
                    tok[s], pos[s], tstep[s], rids[s] = t0, plen, 0, req.rid

            active = np.array([r is not None for r in slots])
            if not active.any():
                continue

            # ---- one fused decode chunk --------------------------------
            caches, tokj, posj, tstepj, toksj = self._chunk(
                self.params, caches, jnp.asarray(tok), jnp.asarray(pos),
                jnp.asarray(tstep), jnp.asarray(rids),
                jnp.asarray(active), cfg.decode_chunk)
            self.stats.chunk_calls += 1
            # np.array (not asarray): device outputs view as read-only, and
            # the admission path writes slots in place
            tok, pos, tstep = (np.array(tokj), np.array(posj),
                               np.array(tstepj))
            toks = np.asarray(toksj)                      # (B, chunk)

            # ---- harvest + evict ---------------------------------------
            for s in range(B):
                req = slots[s]
                if req is None:
                    continue
                for t in toks[s]:
                    req.generated.append(int(t))
                    self.stats.tokens += 1
                    if cfg.eos_id >= 0 and int(t) == cfg.eos_id:
                        finish(s, req, "eos")
                        break
                    if len(req.generated) >= req.max_new_tokens:
                        finish(s, req, "length")
                        break
        return out
