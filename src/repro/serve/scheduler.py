"""Continuous-batching request scheduler on top of the scan-fused engine.

A fixed pool of ``max_batch`` decode *slots* serves a queue of requests:

  * **admit** — a free slot prefils the next queued request and its caches
    are written into the slot's row of the batched cache pytree (and, in
    paged mode, scattered into freshly allocated KV blocks);
  * **decode** — all slots step together through a fused ``lax.scan`` chunk
    of ``decode_chunk`` tokens (one host roundtrip per chunk, not per
    token), with *per-row* positions (every slot sits at its own depth);
  * **evict** — a request leaves its slot when it emits ``eos_id`` or hits
    its ``max_new_tokens``; its blocks return to the free list and the slot
    is immediately re-admittable.

KV layouts (``cfg.kv``):

  * ``"paged"`` (default) — attention KV lives in a per-layer *block pool*
    ``(n_blocks, block_size, KH, Dh)`` addressed through a per-slot block
    table.  Block 0 is the trash block: idle/evicted slots point at it, so
    their decode writes land in memory nobody reads, and prefill scatters
    use drop-mode sentinels so pad positions write nowhere at all.  A
    request only occupies ``ceil((plen + max_new)/block_size)`` blocks
    (plus ``ceil(window/block_size)`` for sliding-window layers), so short
    requests don't reserve worst-case capacity — admission is bounded by
    free *blocks*, not uniform slot capacity.
  * ``"dense"`` — the PR 3 layout: every slot owns a capacity-sized cache
    row.  Kept as the bit-exactness oracle for the paged path.

Prompt handling (``cfg.buckets``):

  * a tuple of lengths — prompts are right-padded up to a bucket, so
    prefill compiles once per bucket.  Pad exactness: pad positions write
    cache slots *ahead* of the request's position (dense) or are dropped
    outright (paged); the per-row valid mask hides the rest — bit-identical
    to an unpadded prefill.  Sliding-window layers need
    ``max(buckets) <= cfg.window`` (pads would evict real history from the
    rolling prefill cache), and recurrent blocks (R/S) / enc-dec are
    rejected — their prefill state would integrate the pad tokens.
  * ``None`` — exact-length prefill (compiles per distinct prompt length;
    ``cfg.max_prompt`` bounds capacity).  No pad tokens exist, which lifts
    the window limit and admits *every* model family: recurrent (R) and
    SSM (S) state live in dense per-slot rows, and encoder-decoder models
    keep per-slot cross-attention buffers with per-row valid lengths
    (``cn``), so slots can hold encoder contexts of different lengths.

Fault-tolerant serving keeps **per-request reliability accounting**: each
request draws its faults from its own key stream ``fold_in(base, rid)``
folded by its own token index, carried through the batch as an (B, 2) key
array (``FTCtx`` per-row mode).  Row b's fault draws — and its quantization
scales — depend only on request b, so evicting or admitting neighbours
never perturbs another request's generation.  This holds with
``policy.weight_faults`` too: the reference and fused backends draw
*per-row* weight flip words, giving each request its own independent
faulty-weight view of the shared SRAM.  ``ft_backend`` may be
``"reference"`` or ``"fused"`` (the fused Pallas decode kernel — same
draws, bit-identical tokens).

Sharded serving: pass ``mesh=`` and every executable runs under GSPMD with
the serving layout (see ``Scheduler.__init__`` and docs/serving.md §Sharded
serving).  Counter-based RNG keeps every per-request fault stream — and
therefore every temp-0 token — bit-identical to the 1-device run.
"""
from __future__ import annotations

import collections
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.parallel import sharding as S
from repro.parallel.ctx import mesh_ctx


@dataclasses.dataclass
class Request:
    rid: int
    tokens: list                     # prompt token ids
    max_new_tokens: int = 16
    extras: dict | None = None       # e.g. {"patch_embeds": (P, D)} for VLMs
    # filled by the scheduler:
    generated: list = dataclasses.field(default_factory=list)
    finish_reason: str | None = None   # "eos" | "length"


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 4               # concurrent decode slots
    buckets: tuple | None = (8, 16)  # prompt pad lengths; None = exact-length
    max_prompt: int | None = None    # prompt cap when buckets is None
    max_new_tokens: int = 16         # per-request cap (cache headroom)
    decode_chunk: int = 4            # fused scan steps per host roundtrip
    temperature: float = 0.0
    eos_id: int = -1                 # < 0: no EOS eviction
    seed: int = 0
    kv: str = "paged"                # "paged" | "dense" KV-cache layout
    block_size: int = 8              # tokens per KV block (paged)
    n_blocks: int | None = None      # pool size incl. trash block (paged;
    #                                  default: full provisioning)


@dataclasses.dataclass
class SchedStats:
    prefill_calls: int = 0
    insert_calls: int = 0
    chunk_calls: int = 0
    retire_calls: int = 0
    tokens: int = 0
    blocks_in_use_peak: int = 0

    @property
    def roundtrips(self) -> int:
        return (self.prefill_calls + self.insert_calls + self.chunk_calls
                + self.retire_calls)


class Scheduler:
    def __init__(self, model, params, cfg: SchedulerConfig | None = None,
                 policy=None, ft_backend: str = "reference", ft_t=None,
                 ft_interpret: bool = True, mesh=None):
        """``mesh``: a jax Mesh — params are device_put in the serving layout
        (TP over 'model', DP-replicated), the slot caches are sharded per
        ``parallel.sharding.cache_shardings`` (batch over DP, heads over
        'model', paged pools DP-replicated), and all four executables
        (prefill / insert / chunk / retire) run under the mesh's activation
        constraints.  Per-request fault streams are unchanged: threefry is
        counter-based, so a request's draws are bit-identical at TP=1 and
        TP=N (tests/test_serve_sharded.py proves it)."""
        from repro.ft import as_policy
        self.model, self.params = model, params
        self.cfg = cfg or SchedulerConfig()
        self.policy = as_policy(policy)
        self.stats = SchedStats()
        self.mesh = mesh
        ctx = S.make_ctx(mesh) if mesh is not None else None
        if mesh is not None:
            self.params = jax.device_put(
                params, S.param_shardings(params, mesh, no_fsdp=True))

        def _shard_caches(caches):
            if mesh is None:
                return caches
            return jax.lax.with_sharding_constraint(
                caches, S.cache_shardings(caches, mesh))

        mcfg = model.cfg
        kinds = T._layer_kinds(mcfg)
        exact = self.cfg.buckets is None
        if self.cfg.kv not in ("paged", "dense"):
            raise ValueError(f"unknown kv layout {self.cfg.kv!r}")
        if set(kinds) & {"R", "S"} or mcfg.enc_dec:
            if not exact:
                raise ValueError(
                    "bucketed prefill supports attention families only: "
                    "right-padded prompts would integrate pad tokens into "
                    "recurrent/encoder state.  Recurrent (R/S) and enc-dec "
                    "models schedule with buckets=None (exact-length "
                    "prefill); their recurrent/SSM state lives in dense "
                    "per-slot rows under either kv layout")
        self._front = (mcfg.n_frontend_tokens if mcfg.frontend == "vision"
                       else 0)
        if (not exact and "L" in kinds
                and self._front + max(self.cfg.buckets) > mcfg.window):
            raise ValueError(
                f"buckets {self.cfg.buckets} (+ {self._front} frontend "
                f"tokens) exceed the sliding window {mcfg.window}: pad "
                "tokens would evict real history from the rolling cache "
                "(use buckets=None for exact-length prefill)")
        if exact and self.cfg.max_prompt is None:
            raise ValueError("buckets=None (exact-length prefill) needs "
                             "cfg.max_prompt to bound slot capacity")
        if self.policy is not None and ft_backend not in ("reference",
                                                          "fused"):
            raise ValueError(
                "per-request fault streams need ft_backend='reference' or "
                "'fused' (per-row keys, per-row weight-fault streams); the "
                "pallas backend takes a single global key and a static t")

        # cache capacity: every slot can hold the largest admitted prompt
        # plus a full generation
        max_prompt = (self.cfg.max_prompt if exact
                      else max(self.cfg.buckets))
        self.capacity = max_prompt + self.cfg.max_new_tokens + self._front
        self._window = mcfg.window if "L" in kinds else 0
        bs = self.cfg.block_size
        self._wg = -(-self.capacity // bs)
        self._wl = -(-self._window // bs) if self._window else 0
        if self.cfg.kv == "paged":
            self.n_blocks = (self.cfg.n_blocks
                             if self.cfg.n_blocks is not None
                             else 1 + self.cfg.max_batch
                             * (self._wg + self._wl))
            if self.n_blocks < 2:
                raise ValueError("paged KV needs n_blocks >= 2 (block 0 is "
                                 "the trash block)")
        else:
            self.n_blocks = 0

        base = jax.random.PRNGKey(self.cfg.seed)
        ftbase, sbase = jax.random.split(base)
        self._ftbase, self._sbase = ftbase, sbase
        temperature = self.cfg.temperature
        capacity = self.capacity
        window = self._window

        def _ftc(keys):
            if self.policy is None:
                return None
            from repro.models.common import FTCtx
            return FTCtx(self.policy, keys, backend=ft_backend, t=ft_t,
                         interpret=ft_interpret)

        def _sample(logits, keys, tsteps):
            if temperature <= 0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            def one(k, t, lg):
                return jax.random.categorical(
                    jax.random.fold_in(k, t + 1), lg / temperature)
            return jax.vmap(one)(keys, tsteps, logits).astype(jnp.int32)

        def _prefill_one(params, batch1, last_idx, rid):
            # per-request streams: prefill draws from fold(fold(base, rid), 0)
            # (B=1, so a single stream per call is already per-request)
            with mesh_ctx(ctx):
                ftk = jax.random.fold_in(jax.random.fold_in(ftbase, rid), 0)
                caches, logits = model.prefill(params, batch1,
                                               max_len=capacity,
                                               ftc=_ftc(ftk),
                                               last_index=last_idx)
                skey = jax.random.fold_in(sbase, rid)
                tok0 = _sample(logits, skey[None],
                               jnp.full((1,), -1, jnp.int32))
                return caches, tok0[0]

        def _scatter_pool(pool, rows, bt_row, wdw, plen):
            # pool (P, bs, KH, Dh); rows (1, S1, KH, Dh).  Prefill positions
            # land at their logical slot's physical row; positions past the
            # request's real length (bucket pads, capacity growth) get a
            # sentinel index and are dropped — they write nowhere.
            P = pool.shape[0]
            S1 = rows.shape[1]
            idx = jnp.arange(S1)
            valid_n = jnp.minimum(plen, wdw) if wdw else plen
            fi = bt_row[idx // bs] * bs + idx % bs
            fi = jnp.where(idx < valid_n, fi, P * bs)
            pf = pool.reshape(P * bs, *pool.shape[2:])
            return pf.at[fi].set(rows[0], mode="drop").reshape(pool.shape)

        def _insert(caches, c1, slot, plen, bt_g, bt_l):
            # one executable for both layouts: paged attention leaves are
            # scattered through the slot's new block table; dense leaves
            # (dense KV, R/S state, cross-attn buffers) are slot-row writes
            def upd(buf, new, stacked):
                return jax.lax.dynamic_update_slice_in_dim(
                    buf, new, slot, 1 if stacked else 0)

            def layer(pc, dc, kind, stacked):
                wdw = window if kind == "L" else 0
                out = {}
                for nm, sub in pc.items():
                    dsub = dc.get(nm)
                    if (nm == "attn" and isinstance(sub, dict)
                            and "bt" in sub):
                        row = bt_l if wdw else bt_g
                        scat = partial(_scatter_pool, bt_row=row, wdw=wdw,
                                       plen=plen)
                        if stacked:
                            out[nm] = {
                                "k": jax.vmap(scat)(sub["k"], dsub["k"]),
                                "v": jax.vmap(scat)(sub["v"], dsub["v"]),
                                "bt": sub["bt"].at[:, slot].set(row),
                            }
                        else:
                            out[nm] = {
                                "k": scat(sub["k"], dsub["k"]),
                                "v": scat(sub["v"], dsub["v"]),
                                "bt": sub["bt"].at[slot].set(row),
                            }
                    elif nm == "cross":
                        s1e = dsub["ck"].shape[-3]
                        if stacked:
                            start = (0, slot, 0, 0, 0)
                            cn = sub["cn"].at[:, slot].set(s1e)
                        else:
                            start = (slot,) + (0,) * (sub["ck"].ndim - 1)
                            cn = sub["cn"].at[slot].set(s1e)
                        out[nm] = {
                            "ck": jax.lax.dynamic_update_slice(
                                sub["ck"], dsub["ck"], start),
                            "cv": jax.lax.dynamic_update_slice(
                                sub["cv"], dsub["cv"], start),
                            "cn": cn,
                        }
                    else:
                        out[nm] = jax.tree.map(
                            lambda b, n: upd(b, n, stacked), sub, dsub)
                return out

            mcfg_ = model.cfg
            kinds_ = T._layer_kinds(mcfg_)
            if mcfg_.unroll:
                out = {f"l{i}": layer(caches[f"l{i}"], c1[f"l{i}"],
                                      kinds_[i], False)
                       for i in range(len(kinds_))}
            else:
                out = {}
                for si, (pattern, _) in enumerate(mcfg_.segments):
                    out[f"seg{si}"] = {
                        f"s{j}": layer(caches[f"seg{si}"][f"s{j}"],
                                       c1[f"seg{si}"][f"s{j}"], kind, True)
                        for j, kind in enumerate(pattern)}
            return _shard_caches(out)

        def _retire(caches, slot):
            # point the evicted slot's block tables back at the trash block
            # so its (still-stepping) row stops writing into blocks that may
            # be reallocated to a new request
            def one(path, leaf):
                names = [str(getattr(k, "key", "")) for k in path]
                if names and names[-1] == "bt":
                    if names[0].startswith("seg"):
                        return leaf.at[:, slot].set(0)
                    return leaf.at[slot].set(0)
                return leaf
            return jax.tree_util.tree_map_with_path(one, caches)

        def _chunk(params, caches, tok, pos, tstep, rids, active, n_steps):
            act = active.astype(jnp.int32)

            def body(carry, _):
                caches, tok, pos, tstep = carry
                keys = jax.vmap(
                    lambda r, t: jax.random.fold_in(
                        jax.random.fold_in(ftbase, r), t + 1))(rids, tstep)
                caches, logits = model.decode_step(params, caches, tok, pos,
                                                   ftc=_ftc(keys))
                skeys = jax.vmap(jax.random.fold_in)(
                    jnp.broadcast_to(sbase, (rids.shape[0],) + sbase.shape),
                    rids)
                nxt = _sample(logits, skeys, tstep)
                tok = jnp.where(active, nxt, tok)
                pos = pos + act
                tstep = tstep + act
                return (_shard_caches(caches), tok, pos, tstep), nxt

            with mesh_ctx(ctx):
                (caches, tok, pos, tstep), toks = jax.lax.scan(
                    body, (caches, tok, pos, tstep), None, length=n_steps)
            return caches, tok, pos, tstep, jnp.moveaxis(toks, 0, 1)

        self._prefill_one = jax.jit(_prefill_one)
        self._insert = jax.jit(_insert, donate_argnums=(0,))
        self._retire_fn = jax.jit(_retire, donate_argnums=(0,))
        self._chunk = jax.jit(_chunk, static_argnums=(7,),
                              donate_argnums=(1,))

    # ------------------------------------------------------------ helpers --
    def _bucket(self, n: int) -> int:
        if self.cfg.buckets is None:
            if n > self.cfg.max_prompt:
                raise ValueError(f"prompt length {n} exceeds cfg.max_prompt "
                                 f"{self.cfg.max_prompt}")
            return n
        for b in sorted(self.cfg.buckets):
            if n <= b:
                return b
        raise ValueError(f"prompt length {n} exceeds largest bucket "
                         f"{max(self.cfg.buckets)}")

    def _make_batch1(self, req: Request):
        L = len(req.tokens)
        Lb = self._bucket(L)
        toks = np.zeros((1, Lb), np.int32)
        toks[0, :L] = req.tokens
        batch1 = {"tokens": jnp.asarray(toks)}
        for k, v in (req.extras or {}).items():
            batch1[k] = jnp.asarray(v)[None]
        last_idx = jnp.asarray([self._front + L - 1], jnp.int32)
        return batch1, last_idx, self._front + L

    def _blocks_needed(self, plen: int, max_new: int) -> int:
        if self.cfg.kv != "paged":
            return 0
        bs = self.cfg.block_size
        total = min(plen + max_new, self.capacity)
        need = -(-total // bs)
        if self._window:
            need += -(-min(total, self._window) // bs)
        return need

    def _init_caches(self, B: int):
        if self.cfg.kv == "paged":
            enc_len = (self.capacity - self.cfg.max_new_tokens
                       if self.model.cfg.enc_dec else None)
            return self.model.init_cache(
                B, self.capacity, paged=(self.cfg.block_size, self.n_blocks),
                enc_len=enc_len)
        return self.model.init_cache(B, self.capacity)

    # ---------------------------------------------------------------- run --
    def run(self, requests) -> dict:
        """Serve `requests` to completion; returns {rid: Request} with
        ``generated`` / ``finish_reason`` filled."""
        cfg = self.cfg
        B = cfg.max_batch
        bs = cfg.block_size
        self.stats = SchedStats()
        seen_rids = set()
        for req in requests:
            plen = self._front + self._bucket(len(req.tokens))  # fail fast
            if req.rid in seen_rids:
                raise ValueError(
                    f"duplicate request id {req.rid}: results are keyed by "
                    "rid and the per-request fault streams derive from it")
            seen_rids.add(req.rid)
            if req.max_new_tokens > cfg.max_new_tokens:
                raise ValueError(
                    f"request {req.rid} wants {req.max_new_tokens} tokens "
                    f"but the slot capacity budgets cfg.max_new_tokens="
                    f"{cfg.max_new_tokens}: decoding past capacity would "
                    "overwrite cache history")
            if self.model.cfg.enc_dec and req.extras:
                fl = np.asarray(req.extras["frames"]).shape[0]
                if fl > self.capacity - cfg.max_new_tokens:
                    raise ValueError(
                        f"request {req.rid} encoder input length {fl} "
                        f"exceeds the cross-attention capacity "
                        f"{self.capacity - cfg.max_new_tokens} "
                        "(cfg.max_prompt)")
            if (cfg.kv == "paged"
                    and self._blocks_needed(plen, req.max_new_tokens)
                    > self.n_blocks - 1):
                raise ValueError(
                    f"request {req.rid} needs "
                    f"{self._blocks_needed(plen, req.max_new_tokens)} KV "
                    f"blocks but the pool has {self.n_blocks - 1} "
                    "allocatable: raise cfg.n_blocks or block_size")
            req.generated = []              # a re-submitted Request restarts
            req.finish_reason = None
        queue = collections.deque(requests)
        slots: list[Request | None] = [None] * B
        out = {}

        caches = self._init_caches(B)
        if self.mesh is not None:
            caches = jax.device_put(
                caches, S.cache_shardings(caches, self.mesh))
        tok = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        tstep = np.zeros((B,), np.int32)
        rids = np.zeros((B,), np.int32)
        free_blocks = collections.deque(range(1, self.n_blocks))
        slot_blocks: list[list] = [[] for _ in range(B)]

        def alloc_tables(plen, max_new):
            """Pop blocks for a request; return (bt_g, bt_l) table rows."""
            total = min(plen + max_new, self.capacity)
            g_need = -(-total // bs)
            l_need = (-(-min(total, self._window) // bs)
                      if self._window else 0)
            got = [free_blocks.popleft() for _ in range(g_need + l_need)]
            bt_g = np.zeros((self._wg,), np.int32)
            bt_g[:g_need] = got[:g_need]
            bt_l = np.zeros((max(self._wl, 1),), np.int32)
            if l_need:
                bt_l[:l_need] = got[g_need:]
            return got, jnp.asarray(bt_g), jnp.asarray(bt_l)

        def release(s):
            if cfg.kv == "paged":
                free_blocks.extend(slot_blocks[s])
                slot_blocks[s] = []

        def finish(s, req, reason):
            req.finish_reason = reason
            out[req.rid] = req
            slots[s] = None
            release(s)

        while queue or any(s is not None for s in slots):
            # ---- admit into free slots (a request that finishes at
            # prefill — EOS first token or max_new_tokens == 1 — does not
            # use up the slot's turn; the slot retries the queue) ---------
            admitted = 0
            for s in range(B):
                while slots[s] is None and queue:
                    req = queue[0]
                    need = self._blocks_needed(
                        self._front + self._bucket(len(req.tokens)),
                        req.max_new_tokens)
                    if need > len(free_blocks):
                        break               # wait for evictions to free blocks
                    queue.popleft()
                    batch1, last_idx, plen = self._make_batch1(req)
                    c1, tok0 = self._prefill_one(
                        self.params, batch1, last_idx,
                        jnp.asarray(req.rid, jnp.int32))
                    self.stats.prefill_calls += 1
                    t0 = int(tok0)
                    req.generated.append(t0)
                    self.stats.tokens += 1
                    if cfg.eos_id >= 0 and t0 == cfg.eos_id:
                        req.finish_reason = "eos"
                        out[req.rid] = req
                        continue
                    if len(req.generated) >= req.max_new_tokens:
                        req.finish_reason = "length"
                        out[req.rid] = req
                        continue
                    if cfg.kv == "paged":
                        got, bt_g, bt_l = alloc_tables(plen,
                                                       req.max_new_tokens)
                        slot_blocks[s] = got
                        in_use = self.n_blocks - 1 - len(free_blocks)
                        self.stats.blocks_in_use_peak = max(
                            self.stats.blocks_in_use_peak, in_use)
                    else:
                        bt_g = jnp.zeros((self._wg,), jnp.int32)
                        bt_l = jnp.zeros((max(self._wl, 1),), jnp.int32)
                    caches = self._insert(caches, c1,
                                          jnp.asarray(s, jnp.int32),
                                          jnp.asarray(plen, jnp.int32),
                                          bt_g, bt_l)
                    self.stats.insert_calls += 1
                    slots[s] = req
                    admitted += 1
                    tok[s], pos[s], tstep[s], rids[s] = t0, plen, 0, req.rid

            active = np.array([r is not None for r in slots])
            if not active.any():
                if queue and not admitted:
                    raise RuntimeError(
                        "scheduler stalled: no active slots and the next "
                        "request cannot be admitted (KV block pool too "
                        "small?)")
                continue

            # ---- one fused decode chunk --------------------------------
            caches, tokj, posj, tstepj, toksj = self._chunk(
                self.params, caches, jnp.asarray(tok), jnp.asarray(pos),
                jnp.asarray(tstep), jnp.asarray(rids),
                jnp.asarray(active), cfg.decode_chunk)
            self.stats.chunk_calls += 1
            # np.array (not asarray): device outputs view as read-only, and
            # the admission path writes slots in place
            tok, pos, tstep = (np.array(tokj), np.array(posj),
                               np.array(tstepj))
            toks = np.asarray(toksj)                      # (B, chunk)

            # ---- harvest + evict ---------------------------------------
            evicted = []
            for s in range(B):
                req = slots[s]
                if req is None:
                    continue
                for t in toks[s]:
                    req.generated.append(int(t))
                    self.stats.tokens += 1
                    if cfg.eos_id >= 0 and int(t) == cfg.eos_id:
                        finish(s, req, "eos")
                        evicted.append(s)
                        break
                    if len(req.generated) >= req.max_new_tokens:
                        finish(s, req, "length")
                        evicted.append(s)
                        break
            if cfg.kv == "paged":
                for s in evicted:
                    caches = self._retire_fn(caches,
                                             jnp.asarray(s, jnp.int32))
                    self.stats.retire_calls += 1
        return out
