"""Algorithm 2 — bit-importance evaluation.

Enumerates (IB_TH, NB_TH) combinations for a fixed important-neuron set,
scoring each with a fault-injection accuracy oracle and the circuit-level
protection cost table, and returns the cheapest setting meeting the accuracy
objective.  Mirrors the paper: high bits are always protected first, NB <= IB.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core import area as A


@dataclasses.dataclass(frozen=True)
class BitConfig:
    ib_th: int
    nb_th: int
    acc: float
    cost: float


def protection_cost_table(bits: int = 8, q_scale: int = 0,
                          policy: str = "configurable",
                          array_dim: int = 32, dot_size: int = 52,
                          s_th: float = 0.05) -> dict[tuple[int, int], float]:
    """Pre-evaluated area cost for every (ib, nb) — the paper pre-builds this
    table so the DSE only does lookups."""
    table = {}
    for ib in range(0, bits + 1):
        for nb in range(0, ib + 1):
            r = A.array_area(array_dim, nb, q_scale, policy,
                             dot_size=dot_size, ib_th=ib)
            table[(ib, nb)] = r["overhead"]
    return table


def get_bit_config(acc_oracle: Callable[[int, int], float],
                   acc_target: float,
                   bits: int = 8,
                   cost_table: dict[tuple[int, int], float] | None = None,
                   **table_kw) -> BitConfig | None:
    """Algorithm 2.  acc_oracle(ib, nb) -> accuracy under fault injection.

    Monotonicity pruning: accuracy is monotone non-decreasing in (ib, nb), so
    if (ib, nb) fails the target, every (ib' <= ib, nb' <= nb) also fails and
    is skipped without running the oracle.
    """
    table = cost_table or protection_cost_table(bits, **table_kw)
    failed: list[tuple[int, int]] = []
    best: BitConfig | None = None
    for ib in range(1, bits + 1):
        for nb in range(0, ib + 1):
            if any(ib <= fi and nb <= fn for fi, fn in failed):
                continue  # pruned (dominated by a known failure)
            cost = table[(ib, nb)]
            if best is not None and cost >= best.cost:
                continue  # cannot improve
            acc = acc_oracle(ib, nb)
            if acc >= acc_target:
                if best is None or cost < best.cost:
                    best = BitConfig(ib, nb, acc, cost)
            else:
                failed.append((ib, nb))
    return best
