"""SCALE-Sim-like analytic performance / IO model of FlexHyCA.

Output-stationary systolic timing for the 2-D array; occupancy model for the
DPPU; DRAM IO accounting including the paper's two extra-IO sources for
TMR-CL: (1) direct DRAM loads when a tile's important-neuron fraction exceeds
DPPU capacity, and (2) important-neuron position tables.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class Gemm:
    """One layer's MAC workload as an (M, K, N) GEMM (convs via im2col)."""
    name: str
    M: int
    K: int
    N: int
    sensitive: bool = False  # layer-level sensitivity (for ARCH/ALG TMR)

    @property
    def macs(self) -> int:
        return self.M * self.K * self.N

    @property
    def weight_bytes(self) -> int:
        return self.K * self.N  # int8

    @property
    def act_bytes(self) -> int:
        return self.M * (self.K + self.N)


def gemm_cycles(g: Gemm, rows: int, cols: int) -> int:
    """Output-stationary pass: each (rows x cols) output tile needs K cycles of
    accumulation plus fill/drain ramps."""
    tiles = math.ceil(g.M / rows) * math.ceil(g.N / cols)
    return tiles * (g.K + rows + cols - 2)


@dataclasses.dataclass(frozen=True)
class DlaConfig:
    array_dim: int = 32
    dot_size: int = 0            # DPPU MAC count (0 = no DPPU)
    data_reuse: bool = True
    freq_ghz: float = 1.0


def base_exec_cycles(layers: Sequence[Gemm], cfg: DlaConfig) -> int:
    return sum(gemm_cycles(g, cfg.array_dim, cfg.array_dim) for g in layers)


def exec_cycles(layers: Sequence[Gemm], cfg: DlaConfig, strategy: str,
                s_th: float = 0.0, protect_sensitive_only: bool = True) -> int:
    """Execution time under a protection strategy.

    strategies: base | crt (circuit TMR, no timing change) | arch (spatial TMR
    => 1/3 the array for protected layers) | alg (temporal TMR => 3x time on
    protected layers) | cl (FlexHyCA: DPPU recompute overlaps the 2-D array;
    slowdown only when the DPPU is the bottleneck).
    """
    total = 0
    for g in layers:
        c = gemm_cycles(g, cfg.array_dim, cfg.array_dim)
        protected = g.sensitive or not protect_sensitive_only
        if strategy in ("base", "crt") or not protected:
            total += c
        elif strategy == "arch":
            # array divided into three voting replicas -> 1/3 the columns
            total += gemm_cycles(g, cfg.array_dim, max(cfg.array_dim // 3, 1))
        elif strategy == "alg":
            total += 3 * c
        elif strategy == "cl":
            dppu_macs_per_cycle = max(cfg.dot_size, 1)
            dppu_cycles = math.ceil(s_th * g.macs / dppu_macs_per_cycle)
            total += max(c, dppu_cycles)  # overlapped; DPPU rarely dominates
        else:
            raise ValueError(strategy)
    return total


def io_bytes(layers: Sequence[Gemm], cfg: DlaConfig, strategy: str,
             s_th: float = 0.0) -> dict:
    """DRAM traffic model.  Returns dict with base/extra/ratio-to-weights."""
    weights = sum(g.weight_bytes for g in layers)
    acts = sum(g.act_bytes for g in layers)
    extra = 0.0
    if strategy == "cl" and s_th > 0:
        for g in layers:
            # (2) position tables: 4B index per important neuron, streamed per
            # tile pass over the layer.
            n_imp = s_th * g.N
            tile_passes = math.ceil(g.M / cfg.array_dim)
            extra += 4.0 * n_imp * tile_passes
            # (1) DPPU direct loads: weight columns of important neurons are
            # re-read; with Data_reuse the activation rows come from the 2-D
            # array cache, otherwise they stream from DRAM too.
            extra += s_th * g.weight_bytes
            if not cfg.data_reuse:
                extra += s_th * g.M * g.K
    elif strategy == "alg":
        # temporal TMR re-reads weights+acts of protected layers twice more
        for g in layers:
            if g.sensitive:
                extra += 2.0 * (g.weight_bytes + g.act_bytes)
    return dict(weights=weights, acts=acts, extra=extra,
                extra_over_weights=extra / max(weights, 1))


def perf_loss(layers: Sequence[Gemm], cfg: DlaConfig, strategy: str,
              s_th: float = 0.0) -> float:
    """Relative execution-time increase vs the unprotected base design."""
    base = base_exec_cycles(layers, cfg)
    return exec_cycles(layers, cfg, strategy, s_th) / max(base, 1) - 1.0


def lm_layer_gemms(n_layers: int, d_model: int, d_ff: int, n_heads: int,
                   d_head: int, n_kv_heads: int, seq: int,
                   sensitive_frac: float = 0.4) -> list[Gemm]:
    """Build a per-layer GEMM workload for a transformer block (used to drive
    the DLA perf model with the assigned architectures' shapes)."""
    out = []
    q = n_heads * d_head
    kv = n_kv_heads * d_head
    n_sens = int(round(sensitive_frac * n_layers))
    for i in range(n_layers):
        s = i < n_sens  # early layers are the sensitive ones (cf. Fig. 5)
        out += [
            Gemm(f"l{i}.wq", seq, d_model, q, s),
            Gemm(f"l{i}.wkv", seq, d_model, 2 * kv, s),
            Gemm(f"l{i}.wo", seq, q, d_model, s),
            Gemm(f"l{i}.ffn_in", seq, d_model, d_ff, s),
            Gemm(f"l{i}.ffn_out", seq, d_ff, d_model, s),
        ]
    return out
