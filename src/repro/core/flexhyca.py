"""FlexHyCA — legacy entry point of the heterogeneous fault-tolerant DLA.

The protection math now lives in :mod:`repro.ft` (``repro.ft.protect_linear``
with the policy registry); this module keeps the original surface alive:

  * :class:`FTConfig` — the flat Table-I design vector, still used to encode
    experiment configs; convert with ``repro.ft.from_ftconfig``.
  * :func:`ft_linear` — deprecation shim over ``repro.ft.protect_linear``
    (reference backend, bit-exact with the historical implementation).
  * :func:`clean_linear` — fault-free quantized reference.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax

from repro.core import quantization as Q


@dataclasses.dataclass(frozen=True)
class FTConfig:
    """Cross-layer fault-tolerance configuration (paper Table I vector V).

    ``strategy`` names a design in the ``repro.ft`` policy registry; the
    seven paper designs are ``base | crt1 | crt2 | crt3 | arch | alg | cl``.
    """
    ber: float = 0.0          # bit error rate of the substrate
    s_th: float = 0.05        # fraction of important neurons
    ib_th: int = 2            # protected high bits of important neurons (DPPU)
    nb_th: int = 1            # protected high bits of ordinary neurons (2-D array)
    q_scale: int = 7          # quantization truncation constraint
    s_policy: str = "uniform"
    dot_size: int = 52
    data_reuse: bool = True
    pe_policy: str = "configurable"
    strategy: str = "cl"
    weight_faults: bool = True
    seed: int = 0


def ft_linear(key: jax.Array, x: jax.Array, w: jax.Array, cfg: FTConfig,
              important: jax.Array | None = None,
              layer_protected: bool = True) -> jax.Array:
    """Deprecated shim: use ``repro.ft.protect_linear`` with a registry
    policy.  Behavior is bit-identical to the historical implementation."""
    from repro import ft
    warnings.warn(
        "repro.core.flexhyca.ft_linear is deprecated; use "
        "repro.ft.protect_linear(key, x, w, ft.get_policy(name, ...))",
        DeprecationWarning, stacklevel=2)
    return ft.protect_linear(key, x, w, ft.from_ftconfig(cfg),
                             important=important,
                             layer_protected=layer_protected)


def clean_linear(x: jax.Array, w: jax.Array, q_scale: int = 0) -> jax.Array:
    """Fault-free quantized reference (for accuracy-delta measurements)."""
    y, _ = Q.fake_quant_linear(x.reshape(-1, x.shape[-1]), w, q_scale=q_scale)
    return y.reshape(*x.shape[:-1], w.shape[1])
