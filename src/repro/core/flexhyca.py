"""FlexHyCA — functional model of the heterogeneous fault-tolerant DLA.

``ft_linear`` is the drop-in linear layer with the paper's full protection
stack.  It computes through the quantized DLA datapath
(``repro.core.quantization``), injects soft errors at a given BER
(``repro.core.faults``), and applies the selective protections:

  * circuit layer — top-``nb_th`` bits of ordinary neurons TMR'd in the 2-D
    array; top-``ib_th`` bits of important neurons TMR'd in the DPPU,
  * architecture layer — important neurons are *recomputed* on the DPPU and
    the DPPU result replaces the 2-D array result (recompute-and-select),
  * algorithm layer — the important-neuron mask comes from Algorithm 1 and the
    quantization is Q_scale-constrained.

The Pallas kernel ``repro.kernels.protected_mm`` implements the same
computation tiled for TPU VMEM; its ``ref.py`` oracle must match this module.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import faults, quantization as Q


@dataclasses.dataclass(frozen=True)
class FTConfig:
    """Cross-layer fault-tolerance configuration (paper Table I vector V)."""
    ber: float = 0.0          # bit error rate of the substrate
    s_th: float = 0.05        # fraction of important neurons
    ib_th: int = 2            # protected high bits of important neurons (DPPU)
    nb_th: int = 1            # protected high bits of ordinary neurons (2-D array)
    q_scale: int = 7          # quantization truncation constraint
    s_policy: str = "uniform"
    dot_size: int = 52
    data_reuse: bool = True
    pe_policy: str = "configurable"
    strategy: str = "cl"      # base | crt1 | crt2 | crt3 | arch | alg | cl
    weight_faults: bool = True
    seed: int = 0


def _strategy_protect(cfg: FTConfig, important: jax.Array | None, n: int):
    """Per-output-channel number of protected high bits + whether the layer is
    TMR'd as a whole (arch/alg spatial/temporal redundancy)."""
    if cfg.strategy == "base":
        return jnp.zeros((n,), jnp.int32), False
    if cfg.strategy.startswith("crt"):
        k = int(cfg.strategy[3:])
        return jnp.full((n,), k, jnp.int32), False
    if cfg.strategy in ("arch", "alg"):
        # whole-layer TMR when the layer is in the protected set; bit field 0
        return jnp.zeros((n,), jnp.int32), True
    if cfg.strategy == "cl":
        imp = jnp.zeros((n,), bool) if important is None else important
        return jnp.where(imp, cfg.ib_th, cfg.nb_th).astype(jnp.int32), False
    raise ValueError(cfg.strategy)


@partial(jax.jit, static_argnames=("cfg", "layer_protected"))
def ft_linear(key: jax.Array, x: jax.Array, w: jax.Array, cfg: FTConfig,
              important: jax.Array | None = None,
              layer_protected: bool = True) -> jax.Array:
    """Fault-tolerant linear: float in/out, faulty quantized DLA inside.

    Args:
      x: (..., K) activations.  w: (K, N) weights.
      important: (N,) bool mask of important output channels (Algorithm 1).
      layer_protected: for arch/alg strategies — whether this layer is in the
        protected (sensitive) set.
    Returns (..., N) float32.
    """
    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1])
    kw, ka, kd = jax.random.split(key, 3)

    q_scale = cfg.q_scale if cfg.strategy == "cl" else 0
    xq, sx = Q.quantize(x2)
    wq, sw = Q.quantize(w)
    if cfg.ber > 0 and cfg.weight_faults:
        wq_f = faults.inject_weight_faults(kw, wq, cfg.ber)
    else:
        wq_f = wq
    acc = Q.saturate(jnp.matmul(xq, wq_f, preferred_element_type=jnp.int32))
    t = Q.choose_trunc_lsb(jnp.max(jnp.abs(acc)), q_scale=q_scale)
    yq = Q.truncate_acc(acc, t)

    protect, whole_layer_tmr = _strategy_protect(cfg, important, w.shape[1])
    if cfg.ber > 0:
        if whole_layer_tmr and layer_protected:
            # spatial/temporal TMR of the whole layer: every bit voted
            yq_f = faults.inject_output_faults(
                ka, yq, cfg.ber, protect_top=jnp.full((w.shape[1],), 8, jnp.int32))
        else:
            yq_f = faults.inject_output_faults(ka, yq, cfg.ber, protect_top=protect)
    else:
        yq_f = yq

    if cfg.strategy == "cl" and cfg.ber > 0 and important is not None:
        # architecture layer: DPPU recomputes important channels on its own
        # (clean weight SRAM + IB_TH-bit-protected MACs) and overrides.
        acc_d = Q.saturate(jnp.matmul(xq, wq, preferred_element_type=jnp.int32))
        yq_d = Q.truncate_acc(acc_d, t)
        yq_d = faults.inject_output_faults(
            kd, yq_d, cfg.ber,
            protect_top=jnp.full((w.shape[1],), cfg.ib_th, jnp.int32))
        yq_f = jnp.where(important[None, :], yq_d, yq_f)

    scale = sx * sw * (2.0 ** t.astype(jnp.float32))
    y = yq_f.astype(jnp.float32) * scale
    return y.reshape(*orig_shape[:-1], w.shape[1])


def clean_linear(x: jax.Array, w: jax.Array, q_scale: int = 0) -> jax.Array:
    """Fault-free quantized reference (for accuracy-delta measurements)."""
    y, _ = Q.fake_quant_linear(x.reshape(-1, x.shape[-1]), w, q_scale=q_scale)
    return y.reshape(*x.shape[:-1], w.shape[1])
