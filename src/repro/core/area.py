"""Circuit-level area model for bit-selective TMR in DLA multiply-accumulate units.

Reproduces the paper's Section III-D / Fig. 14 analysis in gate-equivalents (GE).
All paper figures are *normalized* areas, so a relative model is sufficient; the
constants below are standard cell GE counts (NAND2 = 1 GE).

Geometry of an 8x8 multiplier (shift/array or Wallace): the partial-product
matrix has, at output column c in [0, 15], ``pp(c) = 8 - |c - 7|`` one-bit
terms for c in [0, 14] and carries only at c = 15.  Reducing a column of n
bits costs about (n - 1) compressors (full adders).

Important-bit geometry (paper Fig. 2): with an 8-bit output window [t+7 : t]
truncated out of the 24-bit accumulator, the top ``s`` output bits live at
accumulator bits [t+7-s+1 .. t+7]; the multiplier columns directly feeding
them are columns [m-s+1 .. m] with m = min(t + 7, 15).  Unconstrained, t may
be anything in [0 .. 16]; the union of important columns is then [6+ .. 15]
(for s = 2: columns 6..15, exactly the paper's example).  With the constraint
t >= Q_scale the union shrinks to [Q_scale+8-s .. 15].
"""
from __future__ import annotations

import dataclasses

# gate-equivalent costs (relative; NAND2 = 1)
GE_FA = 5.0        # full adder / 3:2 compressor
GE_HA = 2.5
GE_VOTER = 4.0     # majority voter per protected output bit
GE_MUX2 = 2.5      # 2:1 mux per bit
GE_FF = 4.5        # flip-flop (pipeline reg in the PE)
GE_AND = 1.0

MUL_BITS = 8
MUL_OUT = 16
ACC_BITS = 24
OUT_BITS = 8


def pp_count(c: int, bits: int = MUL_BITS) -> int:
    """Number of partial-product bits in multiplier output column c."""
    hi = 2 * bits - 2
    if c < 0 or c > hi:
        return 0
    return bits - abs(c - (bits - 1))


def column_cost(c: int, bits: int = MUL_BITS, wallace: bool = True) -> float:
    """GE cost of the reduction logic of one output column."""
    n = pp_count(c, bits)
    if n == 0:
        return GE_FA  # carry-resolution cell at the top column
    # n:2 reduction needs ~ (n-1) FAs; array multipliers additionally ripple
    # (modelled as a small constant overhead per column).
    base = max(n - 1, 1) * GE_FA + GE_AND * n  # AND gates forming the pp bits
    if not wallace:
        base *= 1.15  # carry-save array rippling overhead
    return base


def multiplier_cost(bits: int = MUL_BITS, wallace: bool = True) -> float:
    return sum(column_cost(c, bits, wallace) for c in range(2 * bits))


def acc_cost(acc_bits: int = ACC_BITS) -> float:
    """24-bit accumulator: adder + register."""
    return acc_bits * (GE_FA + GE_FF)


def pe_cost(wallace: bool = True) -> float:
    """One unprotected PE (MAC): multiplier + accumulator."""
    return multiplier_cost(wallace=wallace) + acc_cost()


def important_columns(s: int, q_scale: int, bits: int = MUL_BITS,
                      acc_bits: int = ACC_BITS, out_bits: int = OUT_BITS):
    """Union over allowed truncations t >= q_scale of the s multiplier columns
    that directly feed the top-s output bits.  Returns (lo, hi) inclusive."""
    if s <= 0:
        return (0, -1)
    mul_out = 2 * bits
    t_lo = max(q_scale, 0)
    t_hi = acc_bits - out_bits
    m_lo = min(t_lo + out_bits - 1, mul_out - 1)
    lo = max(m_lo - s + 1, 0)
    hi = mul_out - 1  # for large t the window slides past the product top
    if t_hi + out_bits - 1 < mul_out - 1:
        hi = t_hi + out_bits - 1
    return (lo, hi)


def important_acc_bits(s: int, q_scale: int, acc_bits: int = ACC_BITS,
                       out_bits: int = OUT_BITS) -> int:
    """Number of accumulator bit positions that can be important."""
    if s <= 0:
        return 0
    t_lo = max(q_scale, 0)
    t_hi = acc_bits - out_bits
    lo = t_lo + out_bits - s
    hi = min(t_hi + out_bits - 1, acc_bits - 1)
    return max(hi - lo + 1, 0)


@dataclasses.dataclass(frozen=True)
class BitProtectCost:
    """Breakdown of the redundant area of one protected PE (in GE)."""
    mult_redundant: float
    acc_redundant: float
    voters: float
    mux: float

    @property
    def total(self) -> float:
        return self.mult_redundant + self.acc_redundant + self.voters + self.mux


def bit_protect_cost(s: int, q_scale: int = 0, policy: str = "direct",
                     wallace: bool = True) -> BitProtectCost:
    """Extra area to TMR-protect the top-s output bits of one PE.

    policy:
      "direct"       — triplicate every column that can ever be important.
      "configurable" — provide redundant units sized to the largest s columns,
        MUX-steered to the active window; left columns merged to cut fan-out
        (paper Fig. 4).
    """
    if s <= 0:
        return BitProtectCost(0.0, 0.0, 0.0, 0.0)
    lo, hi = important_columns(s, q_scale)
    cols = list(range(lo, hi + 1))
    col_costs = [column_cost(c, wallace=wallace) for c in cols]

    n_acc = important_acc_bits(s, q_scale)
    acc_red = 2.0 * n_acc * GE_FA           # two extra adder slices per bit
    voters = GE_VOTER * (s + n_acc)         # vote the s product bits + acc bits

    if policy == "direct":
        mult_red = 2.0 * sum(col_costs)     # two extra copies of each column
        mux = 0.0
    elif policy == "configurable":
        # redundant capacity = 2 copies of the s largest columns in the region
        largest = sorted(col_costs, reverse=True)[:s]
        mult_red = 2.0 * sum(largest)
        # MUX steering: each redundant FA input selects among the candidate
        # columns; merging adjacent small (left) columns reduces the effective
        # fan-out from len(cols) to ~ceil(len(cols)/2) + s
        fanout = max(len(cols) - s, 0)
        merged_fanout = (fanout + 1) // 2
        n_red_bits = sum(pp_count(c) for c in cols[-s:])
        mux = GE_MUX2 * n_red_bits * max(merged_fanout, 1) * 0.5
    else:
        raise ValueError(f"unknown PE policy {policy!r}")
    return BitProtectCost(mult_red, acc_red, voters, mux)


def protected_pe_cost(s: int, q_scale: int = 0, policy: str = "direct",
                      wallace: bool = True) -> float:
    return pe_cost(wallace) + bit_protect_cost(s, q_scale, policy, wallace).total


def full_tmr_pe_cost(wallace: bool = True) -> float:
    """Classic TMR: triplicate the whole PE + voters on every output bit."""
    return 3.0 * pe_cost(wallace) + GE_VOTER * ACC_BITS


def array_area(array_dim: int, nb_th: int, q_scale: int, pe_policy: str,
               dot_size: int = 0, ib_th: int = 0, wallace: bool = True) -> dict:
    """FlexHyCA computing-array area (GE): 2D array with NB_TH-bit protection
    + DPPU (dot_size MACs) with IB_TH-bit protection.  Returns a breakdown and
    the ratio to an unprotected 2D array (the paper's normalization)."""
    base = array_dim * array_dim * pe_cost(wallace)
    arr = array_dim * array_dim * protected_pe_cost(nb_th, q_scale, pe_policy, wallace)
    dppu = dot_size * protected_pe_cost(ib_th, q_scale, pe_policy, wallace)
    # DPPU adder tree + control + importance-table SRAM interface (small)
    dppu_ctrl = dot_size * GE_FA * 2 + 64 * GE_FF
    total = arr + dppu + dppu_ctrl
    return dict(base=base, array=arr, dppu=dppu + dppu_ctrl, total=total,
                relative=total / base, overhead=(total - base) / base)
