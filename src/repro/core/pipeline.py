"""End-to-end cross-layer optimization driver (paper Fig. 1).

Wires Algorithm 1 (neuron importance) + Algorithm 2 (bit importance) + the
area/perf/IO oracles + Algorithm 3 (Bayesian DSE) into one call:

    result = optimize(model_eval, workload, constraints, fault_rate)

``model_eval`` is an accuracy oracle: ProtectionPolicy -> accuracy-under-
fault.  It is supplied by the benchmark harness (CNN or LM evaluation with
``repro.ft.protect_linear``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from repro.core import area as A
from repro.core import bayesopt as B
from repro.core import perfmodel as P
from repro.ft import ProtectionPolicy, get_policy


@dataclasses.dataclass
class CrossLayerResult:
    policy: ProtectionPolicy | None
    dse: B.DseResult
    area_overhead: float | None

    @property
    def ft(self) -> ProtectionPolicy | None:  # legacy field name
        return self.policy


def _policy_from_cfg(cfg: dict, ber: float) -> ProtectionPolicy:
    """One DSE point (a Table-I assignment dict) as a cross-layer policy."""
    return get_policy("cl", ber=ber, **cfg)


def optimize(acc_oracle: Callable[[ProtectionPolicy], float],
             layers: Sequence[P.Gemm],
             constraints: B.Constraints,
             ber: float,
             array_dim: int = 32,
             iter_max_step: int = 48,
             seed: int = 0,
             space: Sequence[B.Param] | None = None) -> CrossLayerResult:
    """Run the full cross-layer DSE for one fault-rate scenario."""
    space = space or B.table1_space()

    def evaluate(cfg: dict) -> B.EvalResult:
        policy = _policy_from_cfg(cfg, ber)
        alg, arch, circ = policy.algorithm, policy.arch, policy.circuit
        acc = acc_oracle(policy)
        area = A.array_area(array_dim, circ.nb_th, alg.q_scale, circ.pe_policy,
                            dot_size=arch.dot_size,
                            ib_th=circ.ib_th)["overhead"]
        dla = P.DlaConfig(array_dim=array_dim, dot_size=arch.dot_size,
                          data_reuse=arch.data_reuse)
        perf = P.perf_loss(layers, dla, policy.perf_kind, s_th=alg.s_th)
        bw = P.io_bytes(layers, dla, policy.perf_kind,
                        s_th=alg.s_th)["extra_over_weights"]
        return B.EvalResult(area=area, acc=acc, perf_loss=perf, bw_loss=bw)

    dse = B.bayes_design_opt(space, evaluate, constraints,
                             iter_max_step=iter_max_step, seed=seed)
    policy = _policy_from_cfg(dse.best, ber) if dse.best else None
    return CrossLayerResult(
        policy=policy, dse=dse,
        area_overhead=dse.best_eval.area if dse.best_eval else None)
