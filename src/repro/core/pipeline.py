"""End-to-end cross-layer optimization driver (paper Fig. 1).

Wires Algorithm 1 (neuron importance) + Algorithm 2 (bit importance) + the
area/perf/IO oracles + Algorithm 3 (Bayesian DSE) into one call:

    result = optimize(model_eval, workload, constraints, fault_rate)

``model_eval`` is an accuracy oracle: ProtectionPolicy -> accuracy-under-
fault.  It is supplied by the benchmark harness (CNN or LM evaluation with
``repro.ft.protect_linear``).

With ``batch_size > 1`` the DSE proposes q candidates per round
(constant-liar q-EI, see ``repro.core.bayesopt``) and evaluates them in one
shot: the accuracy oracle via ``acc_oracle_batch`` (e.g.
``CnnOracle.accuracy_batch``, which shares one vmapped executable across the
candidates' fault draws) and the analytic area/perf/IO oracles via the
numpy-broadcast batch evaluators below.  End-to-end usage and when q-EI
helps: docs/dse.md.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core import area as A
from repro.core import bayesopt as B
from repro.core import perfmodel as P
from repro.ft import ProtectionPolicy, get_policy


@dataclasses.dataclass
class CrossLayerResult:
    policy: ProtectionPolicy | None
    dse: B.DseResult
    area_overhead: float | None

    @property
    def ft(self) -> ProtectionPolicy | None:  # legacy field name
        return self.policy


# DSE axes that configure *training*, not the deployed protection policy.
# They are stripped before policy construction (a policy pytree must not
# carry training metadata) and routed to the accuracy oracle instead, which
# uses them to pick the fault-aware-trained network that evaluates the
# candidate (see repro.core.evaluate.FatCnnOracle).
TRAIN_AXES = ("fat_ber",)


def _policy_from_cfg(cfg: dict, ber: float) -> ProtectionPolicy:
    """One DSE point (a Table-I assignment dict) as a cross-layer policy."""
    cfg = {k: v for k, v in cfg.items() if k not in TRAIN_AXES}
    return get_policy("cl", ber=ber, **cfg)


# ------------------------------------------------------------------------
# Batched analytic oracles: one numpy-broadcast pass over (batch, layers)
# instead of per-config Python loops.  Bit-for-bit equal to the scalar
# area/perf/IO models (ceil arithmetic mirrors math.ceil on ints/floats).
# ------------------------------------------------------------------------
_pe_cost_v = np.vectorize(A.protected_pe_cost, otypes=[np.float64])


def batch_area_overhead(policies: Sequence[ProtectionPolicy],
                        array_dim: int) -> np.ndarray:
    """(B,) redundant-area overheads, broadcast over the candidate axis."""
    nb = np.array([p.circuit.nb_th for p in policies])
    ib = np.array([p.circuit.ib_th for p in policies])
    qs = np.array([p.algorithm.q_scale for p in policies])
    pe = np.array([p.circuit.pe_policy for p in policies], dtype=object)
    dot = np.array([p.arch.dot_size for p in policies])
    base = array_dim * array_dim * A.pe_cost()
    arr = array_dim * array_dim * _pe_cost_v(nb, qs, pe)
    dppu = dot * _pe_cost_v(ib, qs, pe) + dot * A.GE_FA * 2 + 64 * A.GE_FF
    return (arr + dppu - base) / base


def _gemm_arrays(layers: Sequence[P.Gemm]):
    M = np.array([g.M for g in layers], np.int64)
    K = np.array([g.K for g in layers], np.int64)
    N = np.array([g.N for g in layers], np.int64)
    sens = np.array([g.sensitive for g in layers], bool)
    return M, K, N, sens


def batch_perf_bw(policies: Sequence[ProtectionPolicy],
                  layers: Sequence[P.Gemm],
                  array_dim: int) -> tuple[np.ndarray, np.ndarray]:
    """(B,) perf_loss and (B,) extra-IO-over-weights for a candidate batch.

    Broadcasts the output-stationary cycle model and the DRAM IO model over
    a (batch, layers) grid; candidates are grouped by ``perf_kind`` since the
    kind switches the timing formula, not just its constants.
    """
    M, K, N, sens = _gemm_arrays(layers)
    dim = array_dim
    tiles = -(-M // dim) * (-(-N // dim))          # ceil-div, exact on ints
    cyc = tiles * (K + 2 * dim - 2)                # gemm_cycles(g, dim, dim)
    base = max(int(cyc.sum()), 1)
    macs = M * K * N
    wbytes = K * N
    abytes = M * (K + N)
    weights = max(int(wbytes.sum()), 1)

    perf = np.zeros(len(policies))
    bw = np.zeros(len(policies))
    kinds: dict[str, list[int]] = {}
    for i, p in enumerate(policies):
        kinds.setdefault(p.perf_kind, []).append(i)

    for kind, idxs in kinds.items():
        grp = [policies[i] for i in idxs]
        if kind == "cl":
            s_th = np.array([p.algorithm.s_th for p in grp])[:, None]
            dot = np.maximum(
                np.array([p.arch.dot_size for p in grp]), 1)[:, None]
            reuse = np.array([p.arch.data_reuse for p in grp])[:, None]
            dppu = np.ceil(s_th * macs[None, :] / dot)
            # DPPU overlap applies to the protected (sensitive) layers only
            total = np.where(sens[None, :],
                             np.maximum(cyc[None, :], dppu),
                             cyc[None, :]).sum(1)
            extra = (4.0 * s_th * N[None, :] * (-(-M // dim))[None, :]
                     + s_th * wbytes[None, :]
                     + np.where(reuse, 0.0, s_th * (M * K)[None, :]))
            extra = np.where(s_th > 0, extra, 0.0).sum(1)
        elif kind == "arch":
            cols = max(dim // 3, 1)
            tiles3 = -(-M // dim) * (-(-N // cols))
            cyc3 = tiles3 * (K + dim + cols - 2)
            total = np.where(sens, cyc3, cyc).sum() * np.ones(len(grp))
            extra = np.zeros(len(grp))
        elif kind == "alg":
            total = np.where(sens, 3 * cyc, cyc).sum() * np.ones(len(grp))
            extra = ((wbytes + abytes)[sens].sum() * 2.0
                     * np.ones(len(grp)))
        else:  # base / crt: no timing or IO change
            total = float(cyc.sum()) * np.ones(len(grp))
            extra = np.zeros(len(grp))
        perf[idxs] = total / base - 1.0
        bw[idxs] = extra / weights
    return perf, bw


def evaluate_policies(policies: Sequence[ProtectionPolicy],
                      accs: Sequence[float],
                      layers: Sequence[P.Gemm],
                      array_dim: int) -> list[B.EvalResult]:
    """Assemble EvalResults from batched accuracy + analytic oracles."""
    areas = batch_area_overhead(policies, array_dim)
    perfs, bws = batch_perf_bw(policies, layers, array_dim)
    return [B.EvalResult(area=float(a), acc=float(ac), perf_loss=float(p),
                         bw_loss=float(b))
            for a, ac, p, b in zip(areas, accs, perfs, bws)]


def optimize(acc_oracle: Callable[[ProtectionPolicy], float],
             layers: Sequence[P.Gemm],
             constraints: B.Constraints,
             ber: float,
             array_dim: int = 32,
             iter_max_step: int = 48,
             seed: int = 0,
             space: Sequence[B.Param] | None = None,
             batch_size: int = 1,
             acc_oracle_batch: Callable[[list], Sequence[float]] | None = None,
             ) -> CrossLayerResult:
    """Run the full cross-layer DSE for one fault-rate scenario.

    batch_size: DSE candidates proposed and evaluated per BO round; 1 is the
    sequential paper algorithm.
    acc_oracle_batch: ``list[ProtectionPolicy] -> accuracies`` evaluated in
    one shot (e.g. ``CnnOracle.accuracy_batch``); falls back to mapping
    ``acc_oracle`` when omitted.
    """
    space = space or B.table1_space()

    def evaluate(cfg: dict) -> B.EvalResult:
        policy = _policy_from_cfg(cfg, ber)
        alg, arch, circ = policy.algorithm, policy.arch, policy.circuit
        if "fat_ber" in cfg:
            acc = acc_oracle(policy, fat_ber=cfg["fat_ber"])
        else:
            acc = acc_oracle(policy)
        area = A.array_area(array_dim, circ.nb_th, alg.q_scale, circ.pe_policy,
                            dot_size=arch.dot_size,
                            ib_th=circ.ib_th)["overhead"]
        dla = P.DlaConfig(array_dim=array_dim, dot_size=arch.dot_size,
                          data_reuse=arch.data_reuse)
        perf = P.perf_loss(layers, dla, policy.perf_kind, s_th=alg.s_th)
        bw = P.io_bytes(layers, dla, policy.perf_kind,
                        s_th=alg.s_th)["extra_over_weights"]
        return B.EvalResult(area=area, acc=acc, perf_loss=perf, bw_loss=bw)

    def evaluate_batch(cfgs: list[dict]) -> list[B.EvalResult]:
        pols = [_policy_from_cfg(c, ber) for c in cfgs]
        fat = [c.get("fat_ber", 0.0) for c in cfgs] if any(
            "fat_ber" in c for c in cfgs) else None
        if acc_oracle_batch is not None:
            accs = list(acc_oracle_batch(pols) if fat is None
                        else acc_oracle_batch(pols, fat_bers=fat))
        elif fat is not None:
            accs = [acc_oracle(p, fat_ber=fb) for p, fb in zip(pols, fat)]
        else:
            accs = [acc_oracle(p) for p in pols]
        return evaluate_policies(pols, accs, layers, array_dim)

    dse = B.bayes_design_opt(space, evaluate, constraints,
                             iter_max_step=iter_max_step, seed=seed,
                             batch_size=batch_size,
                             evaluate_batch=evaluate_batch)
    policy = _policy_from_cfg(dse.best, ber) if dse.best else None
    return CrossLayerResult(
        policy=policy, dse=dse,
        area_overhead=dse.best_eval.area if dse.best_eval else None)
