"""End-to-end cross-layer optimization driver (paper Fig. 1).

Wires Algorithm 1 (neuron importance) + Algorithm 2 (bit importance) + the
area/perf/IO oracles + Algorithm 3 (Bayesian DSE) into one call:

    result = optimize(model_eval, workload, constraints, fault_rate)

``model_eval`` is an accuracy oracle: FTConfig -> accuracy-under-fault.  It is
supplied by the benchmark harness (CNN or LM evaluation with ``ft_linear``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from repro.core import area as A
from repro.core import bayesopt as B
from repro.core import perfmodel as P
from repro.core.flexhyca import FTConfig


@dataclasses.dataclass
class CrossLayerResult:
    ft: FTConfig | None
    dse: B.DseResult
    area_overhead: float | None


def _ft_from_cfg(cfg: dict, ber: float) -> FTConfig:
    return FTConfig(ber=ber, s_th=cfg["s_th"], ib_th=cfg["ib_th"],
                    nb_th=cfg["nb_th"], q_scale=cfg["q_scale"],
                    s_policy=cfg["s_policy"], dot_size=cfg["dot_size"],
                    data_reuse=cfg["data_reuse"], pe_policy=cfg["pe_policy"],
                    strategy="cl")


def optimize(acc_oracle: Callable[[FTConfig], float],
             layers: Sequence[P.Gemm],
             constraints: B.Constraints,
             ber: float,
             array_dim: int = 32,
             iter_max_step: int = 48,
             seed: int = 0,
             space: Sequence[B.Param] | None = None) -> CrossLayerResult:
    """Run the full cross-layer DSE for one fault-rate scenario."""
    space = space or B.table1_space()

    def evaluate(cfg: dict) -> B.EvalResult:
        ft = _ft_from_cfg(cfg, ber)
        acc = acc_oracle(ft)
        area = A.array_area(array_dim, ft.nb_th, ft.q_scale, ft.pe_policy,
                            dot_size=ft.dot_size, ib_th=ft.ib_th)["overhead"]
        dla = P.DlaConfig(array_dim=array_dim, dot_size=ft.dot_size,
                          data_reuse=ft.data_reuse)
        perf = P.perf_loss(layers, dla, "cl", s_th=ft.s_th)
        bw = P.io_bytes(layers, dla, "cl", s_th=ft.s_th)["extra_over_weights"]
        return B.EvalResult(area=area, acc=acc, perf_loss=perf, bw_loss=bw)

    dse = B.bayes_design_opt(space, evaluate, constraints,
                             iter_max_step=iter_max_step, seed=seed)
    ft = _ft_from_cfg(dse.best, ber) if dse.best else None
    return CrossLayerResult(
        ft=ft, dse=dse,
        area_overhead=dse.best_eval.area if dse.best_eval else None)
