"""Algorithm 1 — gradient-based important-neuron selection.

The model's forward is instrumented with *taps*: identity additions of zero
arrays at every neuron-activation site.  dL/d(tap) is exactly dL/d(activation),
so accumulating |grad| over a calibration set gives the paper's sensitivity
score without modifying model math.  Neurons = output channels.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np


class Probe:
    """Pass through model forward; `tag(name, x)` marks a neuron site."""

    def __init__(self, taps: Mapping[str, jax.Array] | None = None):
        self.taps = taps
        self.shapes: dict[str, tuple] = {}

    def tag(self, name: str, x: jax.Array) -> jax.Array:
        self.shapes[name] = tuple(x.shape)
        if self.taps is None or name not in self.taps:
            return x
        return x + self.taps[name]


def null_probe() -> Probe:
    return Probe(None)


@dataclasses.dataclass
class ImportanceResult:
    # per-site array of per-channel scores (channel = last axis of the site)
    scores: dict[str, np.ndarray]

    def total_neurons(self) -> int:
        return int(sum(v.size for v in self.scores.values()))

    def select(self, s_th: float, policy: str = "uniform") -> dict[str, np.ndarray]:
        """Boolean masks of important neurons per site.

        policy:
          "uniform" — top s_th fraction *within each site* (paper Table II's
            "uniform proportions": matches DPPU sizing per tile).
          "global"  — top s_th fraction across all sites pooled.
        """
        masks = {}
        if policy == "uniform":
            for k, v in self.scores.items():
                n = max(int(round(s_th * v.size)), 1) if s_th > 0 else 0
                thr = -np.inf if n >= v.size else np.partition(v, -n)[-n] if n else np.inf
                masks[k] = v >= thr if n else np.zeros_like(v, bool)
        elif policy == "global":
            allv = np.concatenate([v.ravel() for v in self.scores.values()])
            n = max(int(round(s_th * allv.size)), 1) if s_th > 0 else 0
            thr = np.partition(allv, -n)[-n] if 0 < n <= allv.size else np.inf
            for k, v in self.scores.items():
                masks[k] = v >= thr
        else:
            raise ValueError(policy)
        return masks


def neuron_importance(apply_fn: Callable, params, batches, loss_fn: Callable,
                      channel_only: bool = True) -> ImportanceResult:
    """Accumulate |dL/da| per neuron over a calibration set (Algorithm 1).

    apply_fn(params, batch, probe) -> model output; the model must route every
    neuron site through probe.tag.  loss_fn(output, batch) -> scalar.
    """
    # discover tap sites/shapes with one dry forward
    probe = Probe(None)
    first = batches[0]
    apply_fn(params, first, probe)
    site_shapes = dict(probe.shapes)

    def loss_with_taps(taps, batch):
        p = Probe(taps)
        out = apply_fn(params, batch, p)
        return loss_fn(out, batch)

    grad_fn = jax.jit(jax.grad(loss_with_taps))
    acc = {k: np.zeros(s[-1] if channel_only else s, np.float64)
           for k, s in site_shapes.items()}
    for batch in batches:
        taps = {k: jnp.zeros(s, jnp.float32) for k, s in site_shapes.items()}
        g = grad_fn(taps, batch)
        for k, v in g.items():
            a = np.abs(np.asarray(v, np.float64))
            if channel_only:
                a = a.reshape(-1, a.shape[-1]).sum(0)
            acc[k] += a
    return ImportanceResult(scores=acc)
