"""Int8 symmetric quantization with Q_scale-constrained accumulator truncation.

Models the DLA datapath of the paper bit-exactly:

  int8 activations x int8 weights -> int16 products -> 24-bit accumulator
  -> truncate an 8-bit window [t+7 : t] out of the accumulator -> int8 output

The truncation LSB ``t`` is the per-layer "quantization selection".  The paper's
quantization *constraint* requires ``t >= Q_scale``, which shrinks the set of
multiplier/accumulator bit-columns that can ever feed an important output bit
(see ``repro.core.area``).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

INT8_MAX = 127
ACC_BITS = 24          # paper: "the accumulator data width is 24 bits"
MUL_OUT_BITS = 16      # 8b x 8b -> 16b product
OUT_BITS = 8


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    bits: int = 8
    acc_bits: int = ACC_BITS
    q_scale: int = 0          # minimum allowed truncation LSB (paper's Q_scale)
    per_channel: bool = True  # per-output-channel weight scales


def quantize(x: jax.Array, bits: int = 8, axis=None):
    """Symmetric linear quantization.  Returns (q:int8-valued int32, scale)."""
    qmax = 2 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def saturate(acc: jax.Array, bits: int = ACC_BITS) -> jax.Array:
    """Saturating arithmetic at `bits`-wide two's complement (DLA accumulator)."""
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    return jnp.clip(acc, lo, hi)


def choose_trunc_lsb(acc_absmax: jax.Array, out_bits: int = OUT_BITS,
                     q_scale: int = 0, acc_bits: int = ACC_BITS) -> jax.Array:
    """Pick the truncation LSB t so the 8-bit window [t+out_bits-1 : t] covers
    the accumulator's dynamic range, subject to the constraint t >= q_scale.

    t = max(q_scale, ceil(log2(absmax + 1)) - (out_bits - 1))   (sign bit kept)

    Computed in pure integer math: ceil(log2(a + 1)) == bit_length(a) for
    a >= 1, and bit_length is a popcount over threshold comparisons.  This
    keeps the datapath integer-only end to end (FTL004) and lets the fused
    decode kernel derive the identical t from the accumulator in-kernel.
    """
    a = jnp.maximum(jnp.abs(acc_absmax).astype(jnp.int32), 1)
    # number of magnitude bits needed: bit_length(a)
    thresholds = jnp.asarray([1 << b for b in range(acc_bits)], jnp.int32)
    need = jnp.sum(a[..., None] >= thresholds, axis=-1).astype(jnp.int32)
    t = jnp.maximum(need - (out_bits - 1), 0)
    t = jnp.clip(t, q_scale, acc_bits - out_bits)
    return t


def truncate_acc(acc: jax.Array, t, out_bits: int = OUT_BITS) -> jax.Array:
    """Take the signed window [t+out_bits-1 : t] of the accumulator with
    round-to-nearest and saturation — the DLA requantization step."""
    t = jnp.asarray(t, jnp.int32)
    half = jnp.where(t > 0, 1 << jnp.maximum(t - 1, 0), 0)
    rounded = (acc + half) >> t
    qmax = 2 ** (out_bits - 1) - 1
    return jnp.clip(rounded, -qmax - 1, qmax)


@partial(jax.jit, static_argnames=("q_scale",))
def qmatmul(xq: jax.Array, wq: jax.Array, q_scale: int = 0):
    """Bit-exact DLA matmul: int8 x int8 -> saturating 24-bit acc -> int8 window.

    Args:
      xq: (M, K) int32 holding int8 values.
      wq: (K, N) int32 holding int8 values.
    Returns:
      (yq, t): int8-valued int32 outputs (M, N) and the per-matmul truncation
      LSB t (scalar int32, >= q_scale).
    """
    acc = saturate(jnp.matmul(xq, wq, preferred_element_type=jnp.int32))
    t = choose_trunc_lsb(jnp.max(jnp.abs(acc)), q_scale=q_scale)
    return truncate_acc(acc, t), t


def fake_quant_linear(x: jax.Array, w: jax.Array, q_scale: int = 0):
    """Float-in/float-out linear computed through the quantized DLA datapath.

    Returns (y, aux) where aux carries the integer intermediates needed by the
    fault-injection / protection pipeline.
    """
    xq, sx = quantize(x, axis=None)
    wq, sw = quantize(w, axis=None)
    yq, t = qmatmul(xq, wq, q_scale)
    scale = sx * sw * (2.0 ** t.astype(jnp.float32))
    return yq.astype(jnp.float32) * scale, dict(xq=xq, wq=wq, t=t, sx=sx, sw=sw)


def quant_error(x: jax.Array, q_scale: int) -> jax.Array:
    """Relative RMS error introduced by quantizing through the constrained
    datapath — used to reproduce paper Fig. 11 (Q_scale vs accuracy)."""
    w = jnp.eye(x.shape[-1], dtype=jnp.float32)
    y, _ = fake_quant_linear(x, w, q_scale=q_scale)
    return jnp.sqrt(jnp.mean((y - x) ** 2)) / (jnp.sqrt(jnp.mean(x ** 2)) + 1e-9)
