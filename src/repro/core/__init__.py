"""The paper's contribution: cross-layer fault-tolerant DLA optimization.

Layers:
  algorithm    — importance (Alg.1), bit_importance (Alg.2), quantization
  architecture — perfmodel (+ the DPPU recompute semantics in repro.ft)
  circuit      — faults (BER injection + TMR semantics), area (bit-TMR cost)
  cross-layer  — bayesopt (Alg.3), strategies, pipeline (Fig.1 driver)

The public fault-tolerance API lives in :mod:`repro.ft` (policy registry +
``protect_linear``); ``FTConfig``/``ft_linear`` remain as a compatibility
surface.
"""
from repro.core.bayesopt import Constraints, bayes_design_opt, table1_space  # noqa: F401
from repro.core.flexhyca import FTConfig, clean_linear, ft_linear  # noqa: F401
from repro.core.pipeline import optimize  # noqa: F401
