"""Algorithm 3 — Bayesian cross-layer design-space exploration.

GP (RBF kernel) surrogate + Expected Improvement over the discrete Table-I
space, minimizing redundant chip area subject to accuracy / performance /
bandwidth constraints, with the paper's monotonic pruning: protection
parameters (S_TH, IB_TH, NB_TH) are monotone in both accuracy and area, so a
constraint violation at v prunes every v' with component-wise weaker
protection.

With ``batch_size > 1`` each round proposes q candidates by q-EI with the
constant-liar heuristic (refit the surrogate pretending each picked point
already achieved the incumbent, so the next pick moves elsewhere) and hands
them to ``evaluate_batch`` in one call — the oracle amortizes its
fault-injection executables across the batch (see docs/dse.md).
``batch_size=1`` is the exact sequential Algorithm 3.  Dedup (``seen``) and
monotonic dominance pruning are applied per-candidate at selection time, so
a batch never contains duplicates or configs already known infeasible.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Param:
    name: str
    values: tuple
    # +1: increasing this param increases both accuracy and area (protection
    # strength); 0: no known monotonicity.
    monotone: int = 0


def table1_space() -> list[Param]:
    """The paper's Table I search space."""
    return [
        Param("s_th", tuple(x / 100 for x in range(5, 45, 5)), monotone=+1),
        Param("ib_th", (2, 3, 4), monotone=+1),
        Param("nb_th", (1, 2, 3), monotone=+1),
        Param("q_scale", tuple(range(1, 17)), monotone=0),
        Param("s_policy", ("uniform", "global"), monotone=0),
        Param("dot_size", (8, 16, 32, 52, 64, 128, 256), monotone=0),
        Param("data_reuse", (True, False), monotone=0),
        Param("pe_policy", ("direct", "configurable"), monotone=0),
    ]


def fat_table1_space(fat_bers: tuple = (0.0, 1e-3, 2e-3)) -> list[Param]:
    """Table I extended with the training-time axis: ``fat_ber`` selects how
    much fault pressure the network was *trained* through (fault-aware
    training).  A FAT-hardened network tolerates more deployment faults, so
    the DSE can trade protection hardware against training exposure.  Not
    marked monotone: higher fat_ber helps accuracy-under-fault but is not a
    protection-strength knob (it costs nothing in area)."""
    return table1_space() + [Param("fat_ber", tuple(fat_bers), monotone=0)]


@dataclasses.dataclass
class EvalResult:
    area: float          # redundant-area overhead (objective, minimized)
    acc: float           # accuracy under fault injection
    perf_loss: float
    bw_loss: float

    def feasible(self, c: "Constraints") -> bool:
        return (self.acc >= c.acc_min and self.perf_loss <= c.perf_max
                and self.bw_loss <= c.bw_max)


@dataclasses.dataclass(frozen=True)
class Constraints:
    acc_min: float
    perf_max: float = 0.10
    bw_max: float = 0.10


class _GP:
    """Minimal GP regressor (RBF + noise), numpy/cholesky."""

    def __init__(self, ls: float = 0.35, noise: float = 1e-4):
        self.ls, self.noise = ls, noise
        self.X = self.y = self.L = self.alpha = None
        self.mu0 = 0.0

    def _k(self, A, B):
        d2 = ((A[:, None, :] - B[None, :, :]) ** 2).sum(-1)
        return np.exp(-0.5 * d2 / self.ls ** 2)

    def fit(self, X: np.ndarray, y: np.ndarray):
        self.X = X
        self.mu0 = float(y.mean())
        self.y = y - self.mu0
        K = self._k(X, X) + self.noise * np.eye(len(X))
        self.L = np.linalg.cholesky(K)
        self.alpha = np.linalg.solve(self.L.T, np.linalg.solve(self.L, self.y))

    def posterior(self, Xs: np.ndarray):
        Ks = self._k(Xs, self.X)
        mu = Ks @ self.alpha + self.mu0
        v = np.linalg.solve(self.L, Ks.T)
        var = np.clip(1.0 - (v ** 2).sum(0), 1e-9, None)
        return mu, var


def _ei(mu, var, best):
    """Expected improvement for minimization."""
    sd = np.sqrt(var)
    z = (best - mu) / sd
    cdf = 0.5 * (1 + np.vectorize(math.erf)(z / math.sqrt(2)))
    pdf = np.exp(-0.5 * z ** 2) / math.sqrt(2 * math.pi)
    return (best - mu) * cdf + sd * pdf


@dataclasses.dataclass
class DseResult:
    best: dict | None
    best_eval: EvalResult | None
    history: list          # (config, EvalResult) — every oracle call
    pruned: int            # configs skipped by monotonic pruning
    evaluations: int


def bayes_design_opt(space: Sequence[Param],
                     evaluate: Callable[[Mapping], EvalResult] | None,
                     constraints: Constraints,
                     iter_max_step: int = 64,
                     n_init: int = 12,
                     n_candidates: int = 256,
                     seed: int = 0,
                     prune_margin: float = 0.02,
                     batch_size: int = 1,
                     evaluate_batch: Callable[[list], list] | None = None,
                     ) -> DseResult:
    """Algorithm 3: Bayesian DSE with monotonic constraint pruning.

    prune_margin: accuracy oracles are stochastic (fault-injection draws), so
    a point only enters the dominance-pruning record when it misses the
    accuracy bar by more than the margin — otherwise one unlucky draw on a
    strongly-protected config would prune the entire space below it.

    batch_size: candidates proposed (and evaluated) per BO round.  1 keeps
    the exact sequential behavior; q > 1 selects by constant-liar q-EI and
    calls ``evaluate_batch`` with up to q configs at once.

    evaluate_batch: ``list[cfg dict] -> list[EvalResult]``, positionally
    aligned.  Defaults to mapping ``evaluate`` over the batch; required when
    ``evaluate`` is None."""
    if evaluate is None and evaluate_batch is None:
        raise ValueError("need evaluate or evaluate_batch")
    if batch_size < 1:
        raise ValueError(f"batch_size must be >= 1, got {batch_size}")

    def eval_many(cfgs: list[dict]) -> list[EvalResult]:
        if evaluate_batch is not None and (len(cfgs) > 1 or evaluate is None):
            return list(evaluate_batch(cfgs))
        return [evaluate(c) for c in cfgs]

    rng = np.random.default_rng(seed)
    names = [p.name for p in space]
    mono = np.array([p.monotone for p in space])

    def sample() -> tuple:
        return tuple(p.values[rng.integers(len(p.values))] for p in space)

    def to_unit(v: tuple) -> np.ndarray:
        out = []
        for p, x in zip(space, v):
            i = p.values.index(x)
            out.append(i / max(len(p.values) - 1, 1))
        return np.array(out)

    # pruning record: unit-space protection coordinates of infeasible points
    infeasible_protection: list[np.ndarray] = []
    mono_idx = np.nonzero(mono > 0)[0]

    def pruned_by_dominance(u: np.ndarray) -> bool:
        if not len(mono_idx):
            return False
        for f in infeasible_protection:
            if np.all(u[mono_idx] <= f[mono_idx] + 1e-12):
                return True
        return False

    seen: set[tuple] = set()
    X, y, history = [], [], []
    pruned = 0
    best_eval: EvalResult | None = None
    best_cfg = None
    penalty = 10.0

    def commit(v: tuple, u: np.ndarray, r: EvalResult):
        """Record one oracle result: surrogate data, pruning record, best."""
        nonlocal best_eval, best_cfg
        cfg = dict(zip(names, v))
        history.append((cfg, r))
        feas = r.feasible(constraints)
        score = r.area if feas else r.area + penalty * (
            max(constraints.acc_min - r.acc, 0) * 10
            + max(r.perf_loss - constraints.perf_max, 0)
            + max(r.bw_loss - constraints.bw_max, 0))
        X.append(u)
        y.append(score)
        if not feas and r.acc < constraints.acc_min - prune_margin:
            infeasible_protection.append(u)  # weaker protection also fails
        if feas and (best_eval is None or r.area < best_eval.area):
            best_eval, best_cfg = r, cfg

    def run_batch(batch: list[tuple[tuple, np.ndarray]]):
        if not batch:
            return
        results = eval_many([dict(zip(names, v)) for v, _ in batch])
        for (v, u), r in zip(batch, results):
            commit(v, u, r)

    def admit(v: tuple) -> np.ndarray | None:
        """Dedup + dominance gate, applied per candidate before batching."""
        nonlocal pruned
        if v in seen:
            return None
        u = to_unit(v)
        if pruned_by_dominance(u):
            pruned += 1
            return None
        seen.add(v)
        return u

    # ---- init: random configs, evaluated in batch_size chunks ------------
    pending: list[tuple[tuple, np.ndarray]] = []
    for _ in range(n_init):
        v = sample()
        u = admit(v)
        if u is not None:
            pending.append((v, u))
        if len(pending) >= batch_size:
            run_batch(pending)
            pending = []
    run_batch(pending)

    gp = _GP()
    step = len(history)
    while step < iter_max_step:
        if len(X) < 2:
            v = sample()
            u = admit(v)
            if u is not None:
                run_batch([(v, u)])
            step += 1  # legacy accounting: a dud sample still burns a step
            continue
        q = min(batch_size, iter_max_step - step)
        cands = [sample() for _ in range(n_candidates)]
        cands = [c for c in cands if c not in seen]
        if not cands:
            break
        U = np.stack([to_unit(c) for c in cands])
        gp.fit(np.stack(X), np.array(y))
        # constant-liar q-EI: after each pick, refit pretending the pick
        # already achieved the incumbent, so EI moves the next pick elsewhere
        Xv, yv = list(X), list(y)
        taken: set[int] = set()
        counted: set[int] = set()   # dominated candidates counted this round
        batch: list[tuple[tuple, np.ndarray]] = []
        for j in range(q):
            if j > 0:
                gp.fit(np.stack(Xv), np.array(yv))
            mu, var = gp.posterior(U)
            ei = _ei(mu, var, min(yv))
            sel = None
            for i in np.argsort(-ei):
                if i in taken or cands[i] in seen:
                    # `seen` catches duplicate tuples sampled at two indices
                    continue
                if pruned_by_dominance(U[i]):
                    if i not in counted:
                        counted.add(i)
                        pruned += 1
                    continue
                sel = int(i)
                break
            if sel is None:
                break
            taken.add(sel)
            seen.add(cands[sel])
            batch.append((cands[sel], U[sel]))
            Xv.append(U[sel])
            yv.append(min(yv))  # the lie: assume the incumbent value
        if not batch:
            break
        run_batch(batch)
        step += len(batch)

    return DseResult(best=best_cfg, best_eval=best_eval, history=history,
                     pruned=pruned, evaluations=len(history))
