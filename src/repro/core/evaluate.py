"""Accuracy-under-fault oracles: connect models to the FT stack.

These drive the paper's experiments: layer sensitivity (Fig. 5/6), strategy
comparison (Fig. 7), S_TH x (IB,NB) surfaces (Fig. 10), Q_scale (Fig. 11),
and the Bayesian DSE's accuracy oracle.

The oracle is vectorized two ways (see docs/dse.md):

  * ``CnnOracle.accuracy`` stacks its ``n_rep`` fault draws onto a vmap axis
    of one jitted ``apply_cnn`` executable.  The executable cache is jit's
    own, keyed on the policy *treedef* (``ber`` is the only pytree leaf, so
    the treedef carries all static structure): structurally-identical
    policies never re-jit.
  * ``CnnOracle.accuracy_batch`` additionally puts *candidates* on the same
    axis.  Table-I knobs that only change numbers, not control flow —
    ``ib_th`` / ``nb_th`` / ``q_scale`` (traced through ``FTCtx.dyn``) and
    ``s_th`` / ``s_policy`` (per-candidate importance masks) — are moved off
    the treedef onto the batch axis, so every candidate that shares the
    canonical structure (recompute / TMR flags) shares one executable.  The
    datapath is integer, so batched results are bit-identical to the looped
    ``n_rep`` path.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.importance import ImportanceResult, neuron_importance
from repro.data.pipeline import vision_batch
from repro.ft import ProtectionPolicy, as_policy, get_policy
from repro.models.cnn import CNNConfig, accuracy, apply_cnn, xent_loss
from repro.models.common import FTCtx


# ---------------------------------------------------------------------------
# Vmapped accuracy executables.  Both are jitted module-level functions whose
# cache key is (cfg, policy treedef, protected set) plus the operand shapes —
# i.e. the executable cache the batched DSE amortizes its compiles against.
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("cfg", "treedef", "protected"))
def _acc_under_fault(params, cfg, imgs, labels, bers, keys, masks, *,
                     treedef, protected):
    """(R,) accuracies: one fault draw per (ber, key) lane, masks shared."""
    def one(ber, key):
        pol = jax.tree_util.tree_unflatten(treedef, (ber,))
        ftc = FTCtx(pol, key, masks,
                    None if protected is None else set(protected))
        return accuracy(apply_cnn(params, cfg, imgs, ftc=ftc), labels)
    return jax.vmap(one)(bers, keys)


@partial(jax.jit, static_argnames=("cfg", "treedef", "protected"))
def _acc_under_fault_dyn(params, cfg, imgs, labels, bers, keys, ibs, nbs,
                         qss, masks, *, treedef, protected):
    """(B,) accuracies with the numeric knobs (and masks) on the vmap axis.

    ``treedef`` is the *canonical* policy structure (see ``_batch_canon``);
    every candidate sharing it rides the same executable regardless of its
    ib_th / nb_th / q_scale / s_th values.
    """
    def one(ber, key, ib, nb, qs, m):
        pol = jax.tree_util.tree_unflatten(treedef, (ber,))
        ftc = FTCtx(pol, key, m,
                    None if protected is None else set(protected),
                    dyn={"ib_th": ib, "nb_th": nb, "q_scale": qs})
        return accuracy(apply_cnn(params, cfg, imgs, ftc=ftc), labels)
    return jax.vmap(one, in_axes=(0, 0, 0, 0, 0, 0))(
        bers, keys, ibs, nbs, qss, masks)


def _batch_canon(pol: ProtectionPolicy) -> ProtectionPolicy:
    """Canonical structure of a policy for cross-candidate batching: keep the
    fields that change the traced program (recompute / TMR flags,
    weight_faults), zero the ones that ride the vmap axis or never enter the
    accuracy datapath (dot_size / data_reuse / pe_policy feed the area & perf
    oracles only)."""
    from repro.ft.policy import AlgorithmLayer, ArchLayer, CircuitLayer
    return ProtectionPolicy(
        name="",
        algorithm=AlgorithmLayer(),
        arch=ArchLayer(recompute=pol.arch.recompute,
                       whole_layer_tmr=pol.arch.whole_layer_tmr,
                       temporal=pol.arch.temporal),
        circuit=CircuitLayer(),
        ber=0.0, weight_faults=pol.weight_faults, seed=0)


@dataclasses.dataclass
class CnnOracle:
    """Fault-injection evaluation for a trained CNN."""
    params: dict
    cfg: CNNConfig
    n_eval: int = 384
    n_rep: int = 3              # fault-draw repetitions averaged
    data_seed: int = 99
    # Evaluation-set difficulty.  1.6 holds clean accuracy near 0.98 (not
    # 1.0): with the saturated-margin 0.4 set, BER 2e-3 moved accuracy by
    # <0.03 and per-layer sensitivities collapsed to <0.01 spread, so the
    # paper's Fig. 5-7 effects were invisible.  Must match the train_cnn
    # default so the oracle evaluates in-distribution.
    noise: float = 1.6

    def __post_init__(self):
        self._imgs, self._labels = vision_batch(
            jax.random.PRNGKey(7), self.n_eval, self.cfg.n_classes,
            self.cfg.hw, noise=self.noise, seed=self.data_seed)
        self._imp: ImportanceResult | None = None
        self._sens_cache: dict = {}

    # ---- Algorithm 1 ---------------------------------------------------
    def importance(self) -> ImportanceResult:
        if self._imp is None:
            batches = [
                vision_batch(jax.random.PRNGKey(i), 64, self.cfg.n_classes,
                             self.cfg.hw, noise=self.noise,
                             seed=self.data_seed)
                for i in range(4)]
            def apply_fn(params, batch, probe):
                return apply_cnn(params, self.cfg, batch[0], probe=probe)
            self._imp = neuron_importance(
                apply_fn, self.params, batches,
                lambda out, batch: xent_loss(out, batch[1]))
        return self._imp

    def masks(self, s_th: float, policy: str = "uniform"):
        return self.importance().select(s_th, policy)

    # ---- accuracy under fault ------------------------------------------
    def _rep_keys(self, seed: int) -> list[jax.Array]:
        return [jax.random.PRNGKey(seed * 97 + r) for r in range(self.n_rep)]

    def accuracy(self, ft: ProtectionPolicy | None, masks=None,
                 protected_layers=None, seed: int = 0) -> float:
        """`ft`: a ProtectionPolicy, a registered policy name, a legacy
        FTConfig, or None for the clean model.

        The ``n_rep`` fault draws run as one vmapped executable (cached on
        the policy treedef); bit-identical to ``_accuracy_looped``."""
        pol = as_policy(ft)
        if pol is None or pol.ber == 0:
            logits = apply_cnn(self.params, self.cfg, self._imgs)
            return float(accuracy(logits, self._labels))
        if masks is None and pol.uses_importance:
            masks = self.masks(pol.algorithm.s_th, pol.algorithm.s_policy)
        _, treedef = jax.tree_util.tree_flatten(pol)
        bers = jnp.full((self.n_rep,), pol.ber, jnp.float32)
        keys = jnp.stack(self._rep_keys(seed))
        masks_j = ({} if masks is None else
                   {k: jnp.asarray(v) for k, v in masks.items()})
        protected = (None if protected_layers is None
                     else frozenset(protected_layers))
        accs = _acc_under_fault(self.params, self.cfg, self._imgs,
                                self._labels, bers, keys, masks_j,
                                treedef=treedef, protected=protected)
        accs = [float(a) for a in np.asarray(accs)]
        return sum(accs) / len(accs)

    def _accuracy_looped(self, ft, masks=None, protected_layers=None,
                         seed: int = 0) -> float:
        """Reference implementation: one forward per fault draw.  Kept as the
        ground truth the vectorized paths are tested bit-identical against."""
        pol = as_policy(ft)
        if pol is None or pol.ber == 0:
            logits = apply_cnn(self.params, self.cfg, self._imgs)
            return float(accuracy(logits, self._labels))
        accs = []
        if masks is None and pol.uses_importance:
            masks = self.masks(pol.algorithm.s_th, pol.algorithm.s_policy)
        for key in self._rep_keys(seed):
            ftc = FTCtx(pol, key, masks, protected_layers)
            logits = apply_cnn(self.params, self.cfg, self._imgs, ftc=ftc)
            accs.append(float(accuracy(logits, self._labels)))
        return sum(accs) / len(accs)

    def accuracy_batch(self, fts, protected_layers=None,
                       seed: int = 0) -> list[float]:
        """Accuracy under fault for a batch of candidate policies.

        Candidates are grouped by canonical structure (``_batch_canon``);
        each group's ``len(group) * n_rep`` (candidate x fault-draw) lanes
        run as one vmapped executable with ``ib_th`` / ``nb_th`` /
        ``q_scale`` traced and per-candidate importance masks stacked on the
        same axis.  Per-candidate results are bit-identical to
        ``accuracy``."""
        pols = [as_policy(f) for f in fts]
        out: list[float | None] = [None] * len(pols)
        clean = [i for i, p in enumerate(pols) if p is None or p.ber == 0]
        if clean:
            v = self.accuracy(None)
            for i in clean:
                out[i] = v
        groups: dict = {}
        for i, p in enumerate(pols):
            if out[i] is None:
                canon = _batch_canon(p)
                key = jax.tree_util.tree_structure(canon)
                groups.setdefault(key, []).append(i)
        protected = (None if protected_layers is None
                     else frozenset(protected_layers))
        R = self.n_rep
        rep_keys = np.stack([np.asarray(k) for k in self._rep_keys(seed)])
        for treedef, idxs in groups.items():
            grp = [pols[i] for i in idxs]
            q = len(grp)
            bers = jnp.asarray(np.repeat([p.ber for p in grp], R), jnp.float32)
            keys = jnp.asarray(np.tile(rep_keys, (q, 1)))
            ibs = jnp.asarray(np.repeat([p.circuit.ib_th for p in grp], R),
                              jnp.int32)
            nbs = jnp.asarray(np.repeat([p.circuit.nb_th for p in grp], R),
                              jnp.int32)
            qss = jnp.asarray(np.repeat([p.algorithm.q_scale for p in grp],
                                        R), jnp.int32)
            masks_j: dict = {}
            if grp[0].uses_importance:
                per_cand = [self.masks(p.algorithm.s_th, p.algorithm.s_policy)
                            for p in grp]
                masks_j = {site: jnp.asarray(np.repeat(
                               np.stack([m[site] for m in per_cand]), R,
                               axis=0))
                           for site in per_cand[0]}
            accs = _acc_under_fault_dyn(
                self.params, self.cfg, self._imgs, self._labels, bers, keys,
                ibs, nbs, qss, masks_j, treedef=treedef, protected=protected)
            accs = np.asarray(accs).reshape(q, R)
            for j, i in enumerate(idxs):
                reps = [float(a) for a in accs[j]]
                out[i] = sum(reps) / len(reps)
        return out  # type: ignore[return-value]

    def layer_names(self) -> list[str]:
        drop = {"head"}
        return [k for k in self.params if k not in drop]

    # ---- Fig. 5: per-layer sensitivity ---------------------------------
    def layer_sensitivity(self, ber: float, seed: int = 0) -> dict[str, float]:
        """Accuracy gain from fully protecting one layer vs none protected.

        Results are memoized in ``_sens_cache`` keyed on everything the
        measurement depends on — ``(ber, seed, n_rep)``.  (``n_rep`` is
        mutable oracle state; keying on it keeps a cached entry from being
        served after the fault-draw count changes.)  ``protected_layers`` is
        *not* part of the key: every entry is computed with the one-layer
        protection sets this method itself chooses."""
        key = (ber, seed, self.n_rep)
        if key in self._sens_cache:
            return self._sens_cache[key]
        base_ft = get_policy("arch", ber=ber)
        none = self.accuracy(base_ft, protected_layers=set(), seed=seed)
        out = {}
        for name in self.layer_names():
            a = self.accuracy(base_ft, protected_layers={name}, seed=seed)
            out[name] = a - none
        self._sens_cache[key] = out
        return out

    # ---- Fig. 6: cumulative protection curve ----------------------------
    def cumulative_protection(self, ber: float, seed: int = 0):
        sens = self.layer_sensitivity(ber, seed)
        order = sorted(sens, key=sens.get, reverse=True)
        ft = get_policy("arch", ber=ber)
        curve = [("none", self.accuracy(ft, protected_layers=set(),
                                        seed=seed))]
        prot: set = set()
        for name in order:
            prot.add(name)
            curve.append((name, self.accuracy(ft, protected_layers=set(prot),
                                              seed=seed)))
        return curve


@lru_cache(maxsize=4)
def trained_cnn(arch: str = "vgg", steps: int = 250) -> CnnOracle:
    """Train (or fetch cached) the reduced paper benchmark CNN."""
    from repro.models.cnn import train_cnn
    cfg = CNNConfig(arch=arch)
    params, acc = train_cnn(jax.random.PRNGKey(0), cfg, steps=steps)
    o = CnnOracle(params, cfg)
    o.clean_acc = acc
    return o


@lru_cache(maxsize=8)
def trained_cnn_fat(arch: str = "vgg", steps: int = 250,
                    fat_ber: float = 0.0,
                    fat_policy: str = "cl",
                    fat_ramp: int | None = None) -> CnnOracle:
    """Fault-aware-trained benchmark CNN (``fat_ber=0`` is ``trained_cnn``).

    Same init key, data stream, and step budget as :func:`trained_cnn`, so
    a (baseline, FAT) pair differs only in the fault pressure seen during
    training — the controlled comparison behind the ``fat_ber`` DSE axis.
    ``fat_ramp`` (default ``steps // 2``) sets the linear BER warm-up."""
    if fat_ber == 0.0:
        return trained_cnn(arch, steps)
    from repro.models.cnn import train_cnn
    cfg = CNNConfig(arch=arch)
    params, acc = train_cnn(jax.random.PRNGKey(0), cfg, steps=steps,
                            fat=fat_policy, fat_ber=fat_ber,
                            fat_ramp=fat_ramp)
    o = CnnOracle(params, cfg)
    o.clean_acc = acc
    return o


class FatCnnOracle:
    """Accuracy oracle over (policy, fat_ber): the DSE's cross-layer +
    *training-time* search surface.

    ``fat_ber`` selects which fault-aware-trained network evaluates the
    candidate (networks are lru-cached per fat value), so the optimizer can
    trade deployment-time protection hardware against training-time fault
    exposure.  The batch path groups candidates by fat value and reuses each
    network's vmapped executable."""

    def __init__(self, arch: str = "vgg", steps: int = 250,
                 fat_policy: str = "cl"):
        self.arch, self.steps, self.fat_policy = arch, steps, fat_policy

    def oracle(self, fat_ber: float = 0.0) -> CnnOracle:
        return trained_cnn_fat(self.arch, self.steps, float(fat_ber),
                               self.fat_policy)

    def __call__(self, ft, fat_ber: float = 0.0, **kw) -> float:
        return self.oracle(fat_ber).accuracy(ft, **kw)

    def batch(self, fts, fat_bers, **kw) -> list[float]:
        out: list[float | None] = [None] * len(fts)
        groups: dict[float, list[int]] = {}
        for i, fb in enumerate(fat_bers):
            groups.setdefault(float(fb), []).append(i)
        for fb, idxs in groups.items():
            accs = self.oracle(fb).accuracy_batch([fts[i] for i in idxs], **kw)
            for j, i in enumerate(idxs):
                out[i] = accs[j]
        return out  # type: ignore[return-value]
