"""Accuracy-under-fault oracles: connect models to the FT stack.

These drive the paper's experiments: layer sensitivity (Fig. 5/6), strategy
comparison (Fig. 7), S_TH x (IB,NB) surfaces (Fig. 10), Q_scale (Fig. 11),
and the Bayesian DSE's accuracy oracle.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import jax
import jax.numpy as jnp

from repro.core.importance import ImportanceResult, neuron_importance
from repro.ft import ProtectionPolicy, as_policy, get_policy
from repro.data.pipeline import vision_batch
from repro.models.cnn import CNNConfig, accuracy, apply_cnn, xent_loss
from repro.models.common import FTCtx


@dataclasses.dataclass
class CnnOracle:
    """Fault-injection evaluation for a trained CNN."""
    params: dict
    cfg: CNNConfig
    n_eval: int = 384
    n_rep: int = 3              # fault-draw repetitions averaged
    data_seed: int = 99
    noise: float = 0.4

    def __post_init__(self):
        self._imgs, self._labels = vision_batch(
            jax.random.PRNGKey(7), self.n_eval, self.cfg.n_classes,
            self.cfg.hw, noise=self.noise, seed=self.data_seed)
        self._imp: ImportanceResult | None = None
        self._sens_cache: dict = {}

    # ---- Algorithm 1 ---------------------------------------------------
    def importance(self) -> ImportanceResult:
        if self._imp is None:
            batches = [
                vision_batch(jax.random.PRNGKey(i), 64, self.cfg.n_classes,
                             self.cfg.hw, noise=self.noise,
                             seed=self.data_seed)
                for i in range(4)]
            def apply_fn(params, batch, probe):
                return apply_cnn(params, self.cfg, batch[0], probe=probe)
            self._imp = neuron_importance(
                apply_fn, self.params, batches,
                lambda out, batch: xent_loss(out, batch[1]))
        return self._imp

    def masks(self, s_th: float, policy: str = "uniform"):
        return self.importance().select(s_th, policy)

    # ---- accuracy under fault ------------------------------------------
    def accuracy(self, ft: ProtectionPolicy | None, masks=None,
                 protected_layers=None, seed: int = 0) -> float:
        """`ft`: a ProtectionPolicy, a registered policy name, a legacy
        FTConfig, or None for the clean model."""
        pol = as_policy(ft)
        if pol is None or pol.ber == 0:
            logits = apply_cnn(self.params, self.cfg, self._imgs)
            return float(accuracy(logits, self._labels))
        accs = []
        if masks is None and pol.uses_importance:
            masks = self.masks(pol.algorithm.s_th, pol.algorithm.s_policy)
        for r in range(self.n_rep):
            ftc = FTCtx(pol, jax.random.PRNGKey(seed * 97 + r), masks,
                        protected_layers)
            logits = apply_cnn(self.params, self.cfg, self._imgs, ftc=ftc)
            accs.append(float(accuracy(logits, self._labels)))
        return sum(accs) / len(accs)

    def layer_names(self) -> list[str]:
        drop = {"head"}
        return [k for k in self.params if k not in drop]

    # ---- Fig. 5: per-layer sensitivity ---------------------------------
    def layer_sensitivity(self, ber: float, seed: int = 0) -> dict[str, float]:
        """Accuracy gain from fully protecting one layer vs none protected."""
        key = (ber, seed)
        if key in self._sens_cache:
            return self._sens_cache[key]
        base_ft = get_policy("arch", ber=ber)
        none = self.accuracy(base_ft, protected_layers=set(), seed=seed)
        out = {}
        for name in self.layer_names():
            a = self.accuracy(base_ft, protected_layers={name}, seed=seed)
            out[name] = a - none
        self._sens_cache[key] = out
        return out

    # ---- Fig. 6: cumulative protection curve ----------------------------
    def cumulative_protection(self, ber: float, seed: int = 0):
        sens = self.layer_sensitivity(ber, seed)
        order = sorted(sens, key=sens.get, reverse=True)
        ft = get_policy("arch", ber=ber)
        curve = [("none", self.accuracy(ft, protected_layers=set(),
                                        seed=seed))]
        prot: set = set()
        for name in order:
            prot.add(name)
            curve.append((name, self.accuracy(ft, protected_layers=set(prot),
                                              seed=seed)))
        return curve


@lru_cache(maxsize=4)
def trained_cnn(arch: str = "vgg", steps: int = 250) -> CnnOracle:
    """Train (or fetch cached) the reduced paper benchmark CNN."""
    from repro.models.cnn import train_cnn
    cfg = CNNConfig(arch=arch)
    params, acc = train_cnn(jax.random.PRNGKey(0), cfg, steps=steps)
    o = CnnOracle(params, cfg)
    o.clean_acc = acc
    return o
