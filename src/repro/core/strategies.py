"""The paper's comparison set of fault-tolerant DLA designs.

Base, TMR-CRT{1,2,3}, TMR-ARCH, TMR-ALG, TMR-CL — each exposing the three
evaluation axes of Section IV: accuracy-under-fault (via ``ft_linear``
configs), execution time (via ``perfmodel``) and redundant chip area (via
``area``).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core import area as A
from repro.core import perfmodel as P
from repro.core.flexhyca import FTConfig


@dataclasses.dataclass(frozen=True)
class Strategy:
    name: str
    ft: FTConfig

    def with_ber(self, ber: float) -> FTConfig:
        return dataclasses.replace(self.ft, ber=ber)

    # ---- area -----------------------------------------------------------
    def area_relative(self, array_dim: int = 32) -> float:
        """Computing-array area relative to the unprotected base array."""
        ft = self.ft
        if self.name == "base":
            return 1.0
        if self.name.startswith("crt"):
            k = int(self.name[3:])
            # circuit-only: every PE protects its top-k bits, quantization
            # unconstrained (q_scale=0), direct redundancy.
            return (A.protected_pe_cost(k, q_scale=0, policy="direct")
                    / A.pe_cost())
        if self.name == "arch":
            # spatial TMR: voting logic + control on the existing array
            return 1.0 + (A.GE_VOTER * A.OUT_BITS * 3) / (A.pe_cost() * 9)
        if self.name == "alg":
            return 1.0  # temporal redundancy: no extra hardware
        if self.name == "cl":
            r = A.array_area(array_dim, ft.nb_th, ft.q_scale, ft.pe_policy,
                             dot_size=ft.dot_size, ib_th=ft.ib_th)
            return r["relative"]
        raise ValueError(self.name)

    # ---- performance ------------------------------------------------------
    def perf_loss(self, layers: Sequence[P.Gemm], array_dim: int = 32) -> float:
        cfg = P.DlaConfig(array_dim=array_dim, dot_size=self.ft.dot_size,
                          data_reuse=self.ft.data_reuse)
        kind = {"base": "base", "crt1": "crt", "crt2": "crt", "crt3": "crt",
                "arch": "arch", "alg": "alg", "cl": "cl"}[self.name]
        return P.perf_loss(layers, cfg, kind, s_th=self.ft.s_th)

    def extra_io(self, layers: Sequence[P.Gemm], array_dim: int = 32) -> float:
        cfg = P.DlaConfig(array_dim=array_dim, dot_size=self.ft.dot_size,
                          data_reuse=self.ft.data_reuse)
        kind = {"base": "base", "crt1": "crt", "crt2": "crt", "crt3": "crt",
                "arch": "arch", "alg": "alg", "cl": "cl"}[self.name]
        return P.io_bytes(layers, cfg, kind, s_th=self.ft.s_th)["extra_over_weights"]


def make_strategies(cl: FTConfig | None = None) -> dict[str, Strategy]:
    """The paper's comparison set.  `cl` is the DSE-optimized TMR-CL config."""
    base = FTConfig(strategy="base")
    out = {
        "base": Strategy("base", base),
        "crt1": Strategy("crt1", dataclasses.replace(base, strategy="crt1")),
        "crt2": Strategy("crt2", dataclasses.replace(base, strategy="crt2")),
        "crt3": Strategy("crt3", dataclasses.replace(base, strategy="crt3")),
        "arch": Strategy("arch", dataclasses.replace(base, strategy="arch")),
        "alg": Strategy("alg", dataclasses.replace(base, strategy="alg")),
        "cl": Strategy("cl", cl or FTConfig(strategy="cl")),
    }
    return out
