"""The paper's comparison set of fault-tolerant DLA designs.

Base, TMR-CRT{1,2,3}, TMR-ARCH, TMR-ALG, TMR-CL — each a
:class:`repro.ft.ProtectionPolicy` from the policy registry, exposing the
three evaluation axes of Section IV: accuracy-under-fault (via
``ft.protect_linear``), execution time (via ``perfmodel``) and redundant chip
area (via ``area``).  All per-design behavior is derived from the policy's
layer structure; there are no name->behavior tables here.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core import area as A
from repro.core import perfmodel as P
from repro.ft import ProtectionPolicy, as_policy, paper_policies


@dataclasses.dataclass(frozen=True)
class Strategy:
    name: str
    policy: ProtectionPolicy

    @property
    def ft(self) -> ProtectionPolicy:  # legacy field name
        return self.policy

    def with_ber(self, ber: float) -> ProtectionPolicy:
        return self.policy.with_ber(ber)

    def _dla(self, array_dim: int) -> P.DlaConfig:
        arch = self.policy.arch
        return P.DlaConfig(array_dim=array_dim, dot_size=arch.dot_size,
                           data_reuse=arch.data_reuse)

    # ---- area -----------------------------------------------------------
    def area_relative(self, array_dim: int = 32) -> float:
        """Computing-array area relative to the unprotected base array."""
        p = self.policy
        kind = p.perf_kind
        if kind == "base":
            return 1.0
        if kind == "crt":
            # circuit-only: every PE protects its top-nb_th bits.
            return (A.protected_pe_cost(p.circuit.nb_th,
                                        q_scale=p.algorithm.q_scale,
                                        policy=p.circuit.pe_policy)
                    / A.pe_cost())
        if kind == "arch":
            # spatial TMR: voting logic + control on the existing array
            return 1.0 + (A.GE_VOTER * A.OUT_BITS * 3) / (A.pe_cost() * 9)
        if kind == "alg":
            return 1.0  # temporal redundancy: no extra hardware
        # cross-layer: selectively hardened array + DPPU
        r = A.array_area(array_dim, p.circuit.nb_th, p.algorithm.q_scale,
                         p.circuit.pe_policy, dot_size=p.arch.dot_size,
                         ib_th=p.circuit.ib_th)
        return r["relative"]

    # ---- performance ------------------------------------------------------
    def perf_loss(self, layers: Sequence[P.Gemm], array_dim: int = 32) -> float:
        return P.perf_loss(layers, self._dla(array_dim), self.policy.perf_kind,
                           s_th=self.policy.algorithm.s_th)

    def extra_io(self, layers: Sequence[P.Gemm], array_dim: int = 32) -> float:
        io = P.io_bytes(layers, self._dla(array_dim), self.policy.perf_kind,
                        s_th=self.policy.algorithm.s_th)
        return io["extra_over_weights"]


def make_strategies(cl=None) -> dict[str, Strategy]:
    """The paper's comparison set.  `cl` is the DSE-optimized TMR-CL design
    (a ProtectionPolicy, a legacy FTConfig, or None for the registry
    default)."""
    pols = paper_policies(as_policy(cl))
    return {name: Strategy(name, p) for name, p in pols.items()}
