"""Soft-error (bit-flip) fault injection, following the paper's protocol:
random bit flips at a given BER on quantized neuron outputs and weights.

Protection semantics
--------------------
A TMR-protected bit only fails if >=2 of 3 replicas flip the same way, so a
protected bit's *residual* flip probability is ``3*ber^2*(1-ber) + ber^3``.
``flip_bits`` takes a per-bit protection mask and applies the residual rate to
protected bits instead of pretending they are perfectly immune.

Partition invariance
--------------------
Every determinism contract in this repo (same fault draws at TP=1 and TP=N,
alone-vs-crowded, checkpoint replay across topologies) rests on the PRNG being
*counter-based*: element ``i`` of a draw is a pure function of (key, i), never
of how the array is laid out across devices.  jax's legacy threefry lowering
does not actually guarantee that under GSPMD — a sharded ``bernoulli`` can
produce different bits than its unsharded trace — so importing this module
switches on ``jax_threefry_partitionable``, the implementation that does.
All draws in the repo go through this module, which keeps the stream
consistent process-wide.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# The contract above is only true under the partitionable threefry lowering;
# the legacy default reorders bits under GSPMD sharding.
jax.config.update("jax_threefry_partitionable", True)


def residual_ber(ber: float) -> float:
    """Residual flip probability of a TMR-voted bit."""
    return 3.0 * ber * ber * (1.0 - ber) + ber ** 3


def fold_stream(key: jax.Array, *indices) -> jax.Array:
    """Derive a subordinate key by folding each index in order.

    This is the repo's key-stream contract written as a function: every
    consumer of fault randomness addresses its draws by a *path* of integer
    coordinates under one root key — ``fold_stream(root, step, microbatch)``
    for training, ``fold_stream(root, call_index)`` for serving — so two
    different coordinates can never replay each other's draws, and a
    checkpoint that restores the coordinate (e.g. the optimizer step
    counter) resumes the exact stream an uninterrupted run would have used.
    Indices may be traced (the train step folds its step counter in-jit).
    """
    for i in indices:
        key = jax.random.fold_in(key, i)
    return key


def fold_axis_index(key: jax.Array, *axis_names: str) -> jax.Array:
    """Per-shard key stream: fold this shard's mesh position into ``key``.

    The jit/GSPMD path needs no per-shard keys — threefry is counter-based,
    so a sharded ``flip_bits`` draws bit-identical values at TP=1 and TP=N.
    Inside ``shard_map`` regions the program *is* per-shard, so any fault
    draw there must address its stream by shard coordinate or every shard
    would replay shard 0's draws.  The contract mirrors :func:`fold_stream`:
    shard ``s`` along one axis draws from ``fold_stream(key, s)``, and
    multiple axes fold in the order given, so a host-side loop over shards
    can reproduce any shard's stream exactly.
    """
    for ax in axis_names:
        key = jax.random.fold_in(key, jax.lax.axis_index(ax))
    return key


def _flip_plane(key, shape, p):
    return jax.random.bernoulli(key, p, shape)


def flip_word(key: jax.Array, shape, ber: float, bits: int,
              protected_mask: int | jax.Array = 0) -> jax.Array:
    """Draw the packed XOR word of a bit-flip event: bit ``b`` of the result
    is set iff bit ``b`` of a `shape`-shaped value flips under BER `ber`.

    This is the randomness of :func:`flip_bits` factored out from the data:
    the draws (key schedule, plane shapes, residual-rate handling) are
    identical, so ``x ^ flip_word(...)`` == ``flip_bits(key, x, ...)`` up to
    sign extension.  The fused decode kernel consumes these packed words
    (8 planes in one int32) instead of raw per-bit planes.
    """
    static_ber = not isinstance(ber, jax.core.Tracer)
    if static_ber:
        ber = float(ber)
    keys = jax.random.split(key, 2 * bits)
    flips = jnp.zeros(shape, jnp.int32)
    prot = jnp.broadcast_to(jnp.asarray(protected_mask, jnp.int32), shape)
    r = residual_ber(ber)
    for b in range(bits):
        bitval = 1 << b
        is_prot = (prot & bitval) != 0
        f_raw = _flip_plane(keys[2 * b], shape, ber)
        if static_ber and r == 0:
            f_res = jnp.zeros(shape, bool)
        else:
            f_res = _flip_plane(keys[2 * b + 1], shape, r)
        f = jnp.where(is_prot, f_res, f_raw)
        flips = flips | jnp.where(f, bitval, 0)
    return flips


def flip_bits(key: jax.Array, x: jax.Array, ber: float, bits: int,
              protected_mask: int | jax.Array = 0,
              signed: bool = True) -> jax.Array:
    """Flip each of the low `bits` bits of two's-complement `x` with prob `ber`.

    Args:
      x: int32 array holding `bits`-wide two's-complement values.
      protected_mask: int bitmask (or int32 array broadcastable to x) of bits
        under TMR protection — those flip at the residual rate instead.
    Returns int32 array, re-signed to `bits` wide.
    """
    # `ber` may be a traced value (policy pytrees put it on a vmap/scan axis);
    # the bernoulli draws are identical either way, so static configs stay
    # bit-exact while traced ones share one compiled executable.
    x = x.astype(jnp.int32)
    mask_all = (1 << bits) - 1
    ux = x & mask_all
    ux = ux ^ flip_word(key, ux.shape, ber, bits, protected_mask)
    if signed:  # sign-extend back
        sign = 1 << (bits - 1)
        ux = jnp.where((ux & sign) != 0, ux - (1 << bits), ux)
    return ux


def top_bits_mask(n_top: int, bits: int) -> int:
    """Bitmask selecting the high `n_top` bits of a `bits`-wide word."""
    n_top = max(0, min(n_top, bits))
    return ((1 << n_top) - 1) << (bits - n_top)


def protect_mask(protect_top: int | jax.Array, bits: int = 8):
    """Per-channel bitmask of TMR-protected bits from a protected-top-bits
    count (int, or an int32 array for per-channel IB_TH/NB_TH selection)."""
    if isinstance(protect_top, int):
        return top_bits_mask(protect_top, bits)
    p = jnp.clip(jnp.asarray(protect_top).astype(jnp.int32), 0, bits)
    mask = ((1 << p) - 1) << (bits - p)
    return jnp.where(p > 0, mask, 0)


def inject_output_faults(key, yq: jax.Array, ber: float, *,
                         bits: int = 8,
                         protect_top: int | jax.Array = 0) -> jax.Array:
    """Inject faults into quantized neuron outputs.

    `protect_top` is the number of protected high bits; may be a per-channel
    int32 array (last-dim broadcast) so important neurons (IB_TH) and ordinary
    neurons (NB_TH) get different protection — the paper's bit dimension.
    """
    mask = protect_mask(protect_top, bits)
    return flip_bits(key, yq, ber, bits, protected_mask=mask)


def inject_weight_faults(key, wq: jax.Array, ber: float, bits: int = 8) -> jax.Array:
    """Faults in weight SRAM (unprotected; the paper protects compute logic)."""
    return flip_bits(key, wq, ber, bits)
