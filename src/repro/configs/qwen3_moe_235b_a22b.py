"""qwen3-moe-235b-a22b [moe] — 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig, MoECfg, RunConfig, reduce_config

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_head=128,
    d_ff=1536,                     # per-expert hidden dim
    vocab=151936,
    block_pattern=("G",),
    moe=MoECfg(n_experts=128, top_k=8, d_ff=1536, capacity_factor=1.25),
    act="silu",
    glu=True,
    rope_theta=1_000_000.0,
)

REDUCED = reduce_config(CONFIG)

RUN = RunConfig(adam_dtype="bfloat16", grad_accum=4)
