"""paligemma-3b [vlm] — SigLIP + gemma backbone.  The SigLIP frontend is a
STUB: input_specs() feeds 256 precomputed patch embeddings that occupy the
first 256 positions of the sequence.  [arXiv:2407.07726; hf]"""
from repro.configs.base import ModelConfig, RunConfig, reduce_config

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_head=256,
    d_ff=16384,
    vocab=257216,
    block_pattern=("G",),
    act="gelu",
    glu=True,
    scale_embeds=True,
    frontend="vision",
    n_frontend_tokens=256,
    rope_theta=10000.0,
)

REDUCED = reduce_config(CONFIG)

RUN = RunConfig(serve_replicated=True)
