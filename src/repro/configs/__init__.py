"""Architecture registry: ``get_config("<arch-id>")``."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ModelConfig, MoECfg, RunConfig, SSMCfg, ShapeConfig, SHAPES, reduce_config)

_MODULES = {
    "gemma2-27b": "gemma2_27b",
    "glm4-9b": "glm4_9b",
    "qwen2-7b": "qwen2_7b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "dbrx-132b": "dbrx_132b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "paligemma-3b": "paligemma_3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "mamba2-2.7b": "mamba2_2_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
}

ARCHS = tuple(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    m = _module(arch)
    return m.REDUCED if reduced else m.CONFIG


def get_run_config(arch: str) -> RunConfig:
    return _module(arch).RUN


def cells(include_skips: bool = False):
    """All (arch, shape) dry-run cells; skips per DESIGN.md unless included."""
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if cfg.supports(shape) or include_skips:
                out.append((arch, shape.name))
    return out
