"""gemma2-27b [dense] — local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig, RunConfig, reduce_config

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_head=128,
    d_ff=36864,
    vocab=256000,
    block_pattern=("L", "G"),      # 1:1 local/global alternation (23 blocks)
    window=4096,
    logit_softcap=30.0,
    attn_softcap=50.0,
    attn_scale=(4608 / 32) ** -0.5,  # query_pre_attn_scalar = d_model/n_heads
    act="gelu",
    glu=True,
    scale_embeds=True,
    post_norm=True,
    rope_theta=10000.0,
)

REDUCED = reduce_config(CONFIG)

RUN = RunConfig(grad_accum=1)
