"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.
d_inner = 2*2560 = 5120, head_dim 64 => 80 SSD heads, state 128.
[arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, RunConfig, SSMCfg, reduce_config

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,                     # attention-free
    n_kv_heads=0,
    d_head=0,
    d_ff=0,                        # no MLP — SSD blocks only
    vocab=50280,
    block_pattern=("S",),
    ssm=SSMCfg(d_state=128, expand=2, head_dim=64, conv_width=4, chunk=128),
    act="silu",
)

REDUCED = reduce_config(CONFIG)

RUN = RunConfig()
