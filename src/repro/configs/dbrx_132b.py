"""dbrx-132b [moe] — 16 experts top-4, fine-grained. [hf:databricks/dbrx-base]"""
from repro.configs.base import ModelConfig, MoECfg, RunConfig, reduce_config

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=10752,                    # per-expert hidden dim
    vocab=100352,
    block_pattern=("G",),
    moe=MoECfg(n_experts=16, top_k=4, d_ff=10752, capacity_factor=1.25),
    act="silu",
    glu=True,
    rope_theta=500_000.0,
)

REDUCED = reduce_config(CONFIG)

RUN = RunConfig(adam_dtype="bfloat16", grad_accum=2)
