"""seamless-m4t-medium [audio] — encoder-decoder transformer backbone
(12 enc + 12 dec, matching hf seamless-m4t-medium's text stacks).  The speech
frontend is a STUB: input_specs() feeds precomputed frame embeddings to the
encoder.  [arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig, RunConfig, reduce_config

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,                   # decoder stack (assigned "12L")
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,                 # MHA
    d_head=64,
    d_ff=4096,
    vocab=256206,
    block_pattern=("G",),
    enc_dec=True,
    n_enc_layers=12,
    act="relu",
    glu=False,
    frontend="audio",
    rope_theta=10000.0,
)

REDUCED = reduce_config(CONFIG)

RUN = RunConfig(tp_hint=2, serve_replicated=True)
