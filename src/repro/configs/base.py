"""Config system: model architecture, input shapes, runtime knobs.

Every assigned architecture gets one module in this package defining CONFIG
(the exact published configuration) and REDUCED (same family, tiny — for CPU
smoke tests).  Select with ``--arch <id>`` in the launchers.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden dim
    capacity_factor: float = 1.25
    aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 128               # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int                  # decoder layers (enc-dec: decoder stack)
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # layer structure: block_pattern repeated, then tail.  kinds:
    #   G global attn, L local/SWA attn, R RG-LRU block, S Mamba2 SSD block
    block_pattern: tuple = ("G",)
    tail: tuple = ()
    window: int = 0                # local-attention window (kind L)
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    attn_scale: float = 0.0        # 0 => 1/sqrt(d_head)
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    act: str = "silu"
    glu: bool = True
    moe: MoECfg | None = None
    ssm: SSMCfg | None = None
    # encoder-decoder (audio):
    enc_dec: bool = False
    n_enc_layers: int = 0
    # modality frontend STUB: precomputed embeddings fed via input_specs
    frontend: str = ""             # "" | "vision" | "audio"
    n_frontend_tokens: int = 0
    tie_embeddings: bool = True
    scale_embeds: bool = False     # gemma-style sqrt(d_model) embed scaling
    post_norm: bool = False        # gemma2 sandwich norms
    norm_eps: float = 1e-6
    rglru_width: int = 0
    rglru_conv: int = 4
    unroll: bool = False           # python-loop layers (reduced/FT configs)

    @property
    def body_layers(self) -> int:
        return self.n_layers - len(self.tail)

    @property
    def n_blocks(self) -> int:
        assert self.body_layers % len(self.block_pattern) == 0, (
            f"{self.name}: {self.body_layers} body layers do not tile "
            f"pattern {self.block_pattern}")
        return self.body_layers // len(self.block_pattern)

    @property
    def segments(self) -> tuple:
        """Scanned layer segments: ((pattern, n_repeats), ...).  The tail is
        its own scan when homogeneous (it always is in the assigned pool)."""
        segs = [(tuple(self.block_pattern), self.n_blocks)]
        if self.tail:
            kinds = set(self.tail)
            assert len(kinds) == 1, "heterogeneous tail unsupported"
            segs.append(((self.tail[0],), len(self.tail)))
        return tuple(segs)

    @property
    def sub_quadratic(self) -> bool:
        """True when no layer does full-context attention (long_500k rule)."""
        kinds = set(self.block_pattern) | set(self.tail)
        if self.enc_dec:
            return False
        return "G" not in kinds

    def supports(self, shape: "ShapeConfig") -> bool:
        if shape.name == "long_500k":
            return self.sub_quadratic
        return True


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Runtime/parallelism knobs (overridable per arch and per shape)."""
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    adam_dtype: str = "float32"    # m/v accumulator dtype (bf16 for huge MoE)
    grad_accum: int = 1            # microbatch scan steps per train step
    attn_block: int = 512          # chunked-attention block size
    loss_chunk: int = 512          # tokens per vocab-projection chunk
    remat: str = "block"           # none | block — checkpoint each layer block
    moe_shard_map: bool = True     # partial-sum EP via shard_map
    seq_shard_attn: bool = False   # sequence-parallel activations (beyond-paper opt)
    compress_grads: bool = False   # int8+error-feedback DP gradient compression
    ft_emu: str = ""               # "" | two_pass | fused — FlexHyCA cost emulation
    ft_s_th: float = 0.05          # important-neuron fraction for ft_emu
    # production layout policies adopted from the §Perf hillclimbs:
    tp_hint: int = 16              # preferred TP width on a 256-chip pod
    serve_replicated: bool = False # decode: TP-only weights (no FSDP psums)


def reduce_config(cfg: ModelConfig, **over) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw: dict = dict(
        name=cfg.name + "-reduced",
        n_layers=len(cfg.block_pattern) * 2 + len(cfg.tail),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=512,
        window=16 if cfg.window else 0,
        n_enc_layers=2 if cfg.enc_dec else 0,
        n_frontend_tokens=8 if cfg.frontend else 0,
        rglru_width=64 if cfg.rglru_width else 0,
        unroll=True,
    )
    if cfg.moe:
        kw["moe"] = MoECfg(n_experts=4, top_k=2, d_ff=32,
                           capacity_factor=cfg.moe.capacity_factor)
    if cfg.ssm:
        kw["ssm"] = SSMCfg(d_state=16, expand=2, head_dim=16, chunk=8)
    kw.update(over)
    return dataclasses.replace(cfg, **kw)
