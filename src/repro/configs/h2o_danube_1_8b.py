"""h2o-danube-1.8b [dense] — llama+mistral mix, sliding-window attention.
[arXiv:2401.16818; hf]"""
from repro.configs.base import ModelConfig, RunConfig, reduce_config

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_head=80,
    d_ff=6912,
    vocab=32000,
    block_pattern=("L",),          # SWA on every layer => sub-quadratic
    window=4096,
    act="silu",
    glu=True,
    rope_theta=10000.0,
)

REDUCED = reduce_config(CONFIG)

RUN = RunConfig(serve_replicated=True)
