"""qwen2-7b [dense] — GQA kv=4, QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs.base import ModelConfig, RunConfig, reduce_config

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_head=128,
    d_ff=18944,
    vocab=152064,
    block_pattern=("G",),
    qkv_bias=True,
    act="silu",
    glu=True,
    rope_theta=1_000_000.0,
)

REDUCED = reduce_config(CONFIG)

RUN = RunConfig(serve_replicated=True)
