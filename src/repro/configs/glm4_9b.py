"""glm4-9b [dense] — RoPE, GQA kv=2, QKV bias. [hf:THUDM/glm-4-9b; hf]"""
from repro.configs.base import ModelConfig, RunConfig, reduce_config

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_head=128,
    d_ff=13696,
    vocab=151552,
    block_pattern=("G",),
    qkv_bias=True,                 # GLM-4 add_qkv_bias
    act="silu",
    glu=True,
    rope_theta=10000.0,
)

REDUCED = reduce_config(CONFIG)

RUN = RunConfig(serve_replicated=True)
