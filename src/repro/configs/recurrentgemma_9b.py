"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2 recurrent blocks
per 1 local-attention block ([R,R,L] x 12 + [R,R] tail = 38 layers).
[arXiv:2402.19427]"""
from repro.configs.base import ModelConfig, RunConfig, reduce_config

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,                  # MQA
    d_head=256,
    d_ff=12288,
    vocab=256000,
    block_pattern=("R", "R", "L"),
    tail=("R", "R"),
    window=2048,
    rglru_width=4096,
    act="gelu",
    glu=True,
    scale_embeds=True,
    rope_theta=10000.0,
)

REDUCED = reduce_config(CONFIG)

RUN = RunConfig(serve_replicated=True)
