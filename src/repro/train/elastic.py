"""Elastic re-meshing: continue training after losing (or gaining) hosts.

The FSDP ('data') axis absorbs the size change; 'model' stays fixed so the
TP layout (and therefore every kernel's tile shapes) is stable.  Because
checkpoints are mesh-agnostic (named leaves, full logical shapes), rescaling
is: build new mesh -> recompute shardings -> restore -> continue.  The
global batch is preserved *exactly* by raising grad_accum when the DP world
shrinks: the new data axis is the largest divisor of the old one that fits
the survivors, so ``new_dp * grad_accum_scale == old_dp`` always holds (a
non-divisor dp would silently change the global batch and the loss curve).
Gained capacity beyond the old world is left idle rather than grown into —
growing dp would need grad_accum *division*, which is not generally integer.

The closed loop lives on the Trainer: ``simulate_device_loss`` ->
``Trainer.handle_device_loss`` (plan_rescale + survivor_mesh +
remesh_restore) -> ``Trainer.run(state, step)``.
"""
from __future__ import annotations

import dataclasses

from repro.parallel import sharding as S
from repro.train import checkpoint as ckpt
from repro.train.train_step import state_shardings


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_dp: int
    new_dp: int
    grad_accum_scale: int   # multiply RunConfig.grad_accum by this

    @property
    def changed(self) -> bool:
        return self.old_dp != self.new_dp


def plan_rescale(old_mesh, surviving_devices: int, model_axis: int) -> ElasticPlan:
    """Choose the largest data axis that fits the survivors.

    Invariants (property-tested in tests/test_elastic_props.py):
      * ``1 <= new_dp <= old_dp`` and ``old_dp % new_dp == 0``
      * ``new_dp * grad_accum_scale == old_dp``  (global batch preserved)
      * nothing changed => identity plan (idempotent)
    """
    old_dp = old_mesh.shape.get("data", 1) * old_mesh.shape.get("pod", 1)
    fit = max(surviving_devices // model_axis, 1)
    # keep global batch: new_dp must divide old_dp so the lost parallelism
    # converts exactly into extra accumulation steps
    new_dp = max(d for d in range(1, old_dp + 1)
                 if old_dp % d == 0 and d <= fit)
    return ElasticPlan(old_dp=old_dp, new_dp=new_dp,
                       grad_accum_scale=old_dp // new_dp)


def simulate_device_loss(mesh, n_lost: int) -> list:
    """Drop the last ``n_lost`` devices of the mesh — the test/benchmark
    stand-in for a real host failure.  Returns the surviving device list."""
    devices = list(mesh.devices.flat)
    if not 0 <= n_lost < len(devices):
        raise ValueError(f"cannot lose {n_lost} of {len(devices)} devices")
    return devices[:len(devices) - n_lost]


def survivor_mesh(plan: ElasticPlan, model_axis: int, devices: list):
    """Build the (data, model) mesh of the rescale plan over survivors."""
    import numpy as np
    from jax.sharding import Mesh

    need = plan.new_dp * model_axis
    if len(devices) < need:
        raise ValueError(f"plan needs {need} devices, {len(devices)} survive")
    grid = np.array(devices[:need]).reshape(plan.new_dp, model_axis)
    return Mesh(grid, ("data", "model"))


def remesh_restore(ckpt_dir: str, like_state, new_mesh):
    """Restore the latest checkpoint onto a new mesh's shardings."""
    sh = state_shardings(like_state, new_mesh)
    state, step, dstate = ckpt.restore(ckpt_dir, like_state, shardings=sh)
    if state is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    return state, step, dstate, S.make_ctx(new_mesh)
