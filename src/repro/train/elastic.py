"""Elastic re-meshing: continue training after losing (or gaining) hosts.

The FSDP ('data') axis absorbs the size change; 'model' stays fixed so the
TP layout (and therefore every kernel's tile shapes) is stable.  Because
checkpoints are mesh-agnostic (named leaves, full logical shapes), rescaling
is: build new mesh -> recompute shardings -> restore -> continue.  The
global batch is preserved by raising grad_accum when the DP world shrinks.
"""
from __future__ import annotations

import dataclasses

from repro.parallel import sharding as S
from repro.train import checkpoint as ckpt
from repro.train.train_step import state_shardings


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_dp: int
    new_dp: int
    grad_accum_scale: int   # multiply RunConfig.grad_accum by this

    @property
    def changed(self) -> bool:
        return self.old_dp != self.new_dp


def plan_rescale(old_mesh, surviving_devices: int, model_axis: int) -> ElasticPlan:
    """Choose the largest data axis that fits the survivors."""
    old_dp = old_mesh.shape.get("data", 1) * old_mesh.shape.get("pod", 1)
    new_dp = max(surviving_devices // model_axis, 1)
    # keep global batch: if dp halves, double accumulation
    scale = max(old_dp // new_dp, 1)
    return ElasticPlan(old_dp=old_dp, new_dp=new_dp, grad_accum_scale=scale)


def remesh_restore(ckpt_dir: str, like_state, new_mesh):
    """Restore the latest checkpoint onto a new mesh's shardings."""
    sh = state_shardings(like_state, new_mesh)
    state, step, dstate = ckpt.restore(ckpt_dir, like_state, shardings=sh)
    if state is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    return state, step, dstate, S.make_ctx(new_mesh)
