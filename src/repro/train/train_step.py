"""Train / prefill / decode step builders with full sharding annotations.

``make_train_step`` returns a jit-compiled (or lowerable) step:
  state = {"params", "m", "v", "step"}            (all sharded per rules)
  step(state, batch) -> (state, metrics)
with optional microbatch gradient accumulation (lax.scan) and int8+error-
feedback gradient compression on the accumulation carry.

Fault-aware training (FAT): passing ``policy=`` threads a
:class:`~repro.models.common.FTCtx` through the forward pass so the model
trains *through* injected faults on the quantized DLA datapath
(``protect_linear_ste``: forward bit-exact faulty, backward clean
straight-through gradients).  The fault-key stream is derived *inside* the
jitted step by folding the optimizer step counter (and the microbatch index
under gradient accumulation) from one root key — no key reuse across steps,
and a run resumed from a checkpoint continues the exact stream because the
step counter restores with the state.  The BER ramp (``fat_ramp``) is a
traced function of the same counter, so the whole schedule runs under one
executable: the policy structure stays static metadata and the per-step BER
is the policy pytree's single dynamic leaf (see docs/training.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.faults import fold_stream
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.parallel import sharding as S
from repro.parallel.ctx import mesh_ctx


def make_loss_fn(model):
    def loss_fn(params, batch, ftc=None):
        loss, metrics = model.loss(params, batch, ftc=ftc)
        return loss, metrics
    return loss_fn


def fat_ber_at(target_ber: float, ramp_steps: int, step):
    """Linear BER warm-up 0 -> ``target_ber`` over ``ramp_steps`` updates.

    ``step`` may be traced (the in-jit optimizer counter): the returned BER
    is then the traced scalar that rides the policy pytree's dynamic leaf.
    Ramping keeps the early optimization on a mostly-clean loss surface so
    FAT reaches the same clean accuracy as a baseline run, then anneals the
    fault pressure up to the deployment operating point.
    """
    step = jnp.asarray(step, jnp.float32)
    frac = (jnp.clip(step / float(ramp_steps), 0.0, 1.0) if ramp_steps > 0
            else jnp.float32(1.0))
    return jnp.float32(target_ber) * frac


def _accumulate(loss_fn, params, batch, n_accum: int, ftc_at=None):
    """Scan over microbatches; returns (loss, grads) averaged.

    ``ftc_at(i)`` builds the fault context for microbatch ``i`` (traced
    index), so under gradient accumulation each microbatch draws from its
    own fold of the step key — the microbatch axis of the key-stream
    contract."""
    if n_accum <= 1:
        ftc = None if ftc_at is None else ftc_at(jnp.int32(0))
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, ftc)
        return loss, grads

    def slice_mb(x):
        b = x.shape[0]
        assert b % n_accum == 0, (b, n_accum)
        return x.reshape(n_accum, b // n_accum, *x.shape[1:])

    mbs = jax.tree.map(slice_mb, batch)

    def body(carry, xs):
        mb, idx = xs
        loss_acc, grads_acc = carry
        ftc = None if ftc_at is None else ftc_at(idx)
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb, ftc)
        grads_acc = jax.tree.map(
            lambda a, g: a + g.astype(a.dtype), grads_acc, grads)
        return (loss_acc + loss, grads_acc), None

    # accumulate in the parameter dtype: an f32 accumulator for a 235B-param
    # MoE costs ~10 GiB/device of extra state; AdamW upcasts to f32 anyway
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
    (loss, grads), _ = jax.lax.scan(
        body, (jnp.zeros(()), zeros), (mbs, jnp.arange(n_accum)))
    inv = 1.0 / n_accum
    return loss * inv, jax.tree.map(lambda g: g * inv, grads)


def init_state(model, key, opt_cfg: AdamWConfig):
    params = model.init(key)
    opt = init_opt_state(params, opt_cfg)
    return {"params": params, **opt}


def state_shardings(state_spec_tree, mesh):
    """Sharding tree for the train state (moments follow their params)."""
    def one(path, leaf):
        names = [getattr(k, "key", None) for k in path]
        if names and names[0] in ("params", "m", "v"):
            sub = path[1:]
            if sub:
                return NamedSharding(mesh, S.param_spec(sub, leaf, mesh))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(one, state_spec_tree)


def make_train_step(model, opt_cfg: AdamWConfig, mesh=None, donate=True,
                    policy=None, ft_ber: float | None = None, ft_key=None,
                    fat_ramp: int = 0, ft_backend: str = "reference",
                    masks=None):
    """Returns (step_fn, jit_step).  With a mesh, in/out shardings are set and
    the model runs under the mesh context so activation constraints apply.

    FAT arguments (all optional; ``policy=None`` is the clean step):
      policy: a ProtectionPolicy or registry name — the fault model the
        network trains through.  Resolved on the host; its structure is
        static, only the per-step BER traces.
      ft_ber: target training BER (defaults to ``policy.ber``).
      ft_key: root PRNG key of the fault stream (defaults to
        ``PRNGKey(policy.seed)``).  Per-step/per-microbatch keys are folded
        from it inside the jitted step: ``fold_stream(ft_key, step, mb)``.
      fat_ramp: steps of linear BER warm-up (see :func:`fat_ber_at`).
      masks: optional per-site importance masks for recompute policies.
    """
    from repro.ft import as_policy
    from repro.models.common import FTCtx

    n_accum = model.run.grad_accum
    loss_fn = make_loss_fn(model)
    pol = as_policy(policy)
    if pol is not None:
        target_ber = float(pol.ber if ft_ber is None else ft_ber)
        root_key = (ft_key if ft_key is not None
                    else jax.random.PRNGKey(pol.seed))

    def step(state, batch):
        ctx = S.make_ctx(mesh) if mesh is not None else None
        with mesh_ctx(ctx):
            ftc_at, fat_metrics = None, {}
            if pol is not None:
                ber_t = fat_ber_at(target_ber, fat_ramp, state["step"])
                pol_t = pol.with_ber(ber_t)
                k_step = fold_stream(root_key, state["step"])

                def ftc_at(i):
                    return FTCtx(pol_t, fold_stream(k_step, i), masks,
                                 backend=ft_backend, ste=True)

                fat_metrics = {"fat_ber": ber_t}
            loss, grads = _accumulate(loss_fn, state["params"], batch,
                                      n_accum, ftc_at)
            opt_state = {"m": state["m"], "v": state["v"],
                         "step": state["step"]}
            new_p, new_opt, om = adamw_update(grads, opt_state,
                                              state["params"], opt_cfg)
        new_state = {"params": new_p, **new_opt}
        return new_state, {"loss": loss, **om, **fat_metrics}

    if mesh is None:
        return step, jax.jit(step, donate_argnums=(0,) if donate else ())

    state_spec = jax.eval_shape(
        lambda k: init_state(model, k, opt_cfg), jax.random.PRNGKey(0))
    st_sh = state_shardings(state_spec, mesh)
    jit_step = jax.jit(
        step,
        in_shardings=(st_sh, None),
        out_shardings=(st_sh, None),
        donate_argnums=(0,) if donate else ())
    return step, jit_step


def make_prefill_step(model, mesh=None):
    def pf(params, batch):
        ctx = S.make_ctx(mesh) if mesh is not None else None
        with mesh_ctx(ctx):
            return model.prefill(params, batch)
    return pf


def make_decode_step(model, mesh=None):
    def dec(params, caches, token, pos):
        ctx = S.make_ctx(mesh) if mesh is not None else None
        with mesh_ctx(ctx):
            return model.decode_step(params, caches, token, pos)
    return dec
