"""Train / prefill / decode step builders with full sharding annotations.

``make_train_step`` returns a jit-compiled (or lowerable) step:
  state = {"params", "m", "v", "step"}            (all sharded per rules)
  step(state, batch) -> (state, metrics)
with optional microbatch gradient accumulation (lax.scan) and int8+error-
feedback gradient compression on the accumulation carry.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.parallel import sharding as S
from repro.parallel.ctx import mesh_ctx


def make_loss_fn(model):
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics
    return loss_fn


def _accumulate(loss_fn, params, batch, n_accum: int):
    """Scan over microbatches; returns (loss, grads) averaged."""
    if n_accum <= 1:
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, grads

    def slice_mb(x):
        b = x.shape[0]
        assert b % n_accum == 0, (b, n_accum)
        return x.reshape(n_accum, b // n_accum, *x.shape[1:])

    mbs = jax.tree.map(slice_mb, batch)

    def body(carry, mb):
        loss_acc, grads_acc = carry
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb)
        grads_acc = jax.tree.map(
            lambda a, g: a + g.astype(a.dtype), grads_acc, grads)
        return (loss_acc + loss, grads_acc), None

    # accumulate in the parameter dtype: an f32 accumulator for a 235B-param
    # MoE costs ~10 GiB/device of extra state; AdamW upcasts to f32 anyway
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
    (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), mbs)
    inv = 1.0 / n_accum
    return loss * inv, jax.tree.map(lambda g: g * inv, grads)


def init_state(model, key, opt_cfg: AdamWConfig):
    params = model.init(key)
    opt = init_opt_state(params, opt_cfg)
    return {"params": params, **opt}


def state_shardings(state_spec_tree, mesh):
    """Sharding tree for the train state (moments follow their params)."""
    def one(path, leaf):
        names = [getattr(k, "key", None) for k in path]
        if names and names[0] in ("params", "m", "v"):
            sub = path[1:]
            if sub:
                return NamedSharding(mesh, S.param_spec(sub, leaf, mesh))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(one, state_spec_tree)


def make_train_step(model, opt_cfg: AdamWConfig, mesh=None, donate=True):
    """Returns (step_fn, jit_step).  With a mesh, in/out shardings are set and
    the model runs under the mesh context so activation constraints apply."""
    n_accum = model.run.grad_accum
    loss_fn = make_loss_fn(model)

    def step(state, batch):
        ctx = S.make_ctx(mesh) if mesh is not None else None
        with mesh_ctx(ctx):
            loss, grads = _accumulate(loss_fn, state["params"], batch, n_accum)
            opt_state = {"m": state["m"], "v": state["v"],
                         "step": state["step"]}
            new_p, new_opt, om = adamw_update(grads, opt_state,
                                              state["params"], opt_cfg)
        new_state = {"params": new_p, **new_opt}
        return new_state, {"loss": loss, **om}

    if mesh is None:
        return step, jax.jit(step, donate_argnums=(0,) if donate else ())

    state_spec = jax.eval_shape(
        lambda k: init_state(model, k, opt_cfg), jax.random.PRNGKey(0))
    st_sh = state_shardings(state_spec, mesh)
    jit_step = jax.jit(
        step,
        in_shardings=(st_sh, None),
        out_shardings=(st_sh, None),
        donate_argnums=(0,) if donate else ())
    return step, jit_step


def make_prefill_step(model, mesh=None):
    def pf(params, batch):
        ctx = S.make_ctx(mesh) if mesh is not None else None
        with mesh_ctx(ctx):
            return model.prefill(params, batch)
    return pf


def make_decode_step(model, mesh=None):
    def dec(params, caches, token, pos):
        ctx = S.make_ctx(mesh) if mesh is not None else None
        with mesh_ctx(ctx):
            return model.decode_step(params, caches, token, pos)
    return dec
