from repro.train.train_step import (  # noqa: F401
    init_state, make_decode_step, make_prefill_step, make_train_step,
    state_shardings)
from repro.train.trainer import Trainer, TrainerConfig  # noqa: F401
