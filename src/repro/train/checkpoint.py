"""Step-atomic sharded checkpointing with async write and resume-latest.

Layout:  <dir>/step_<N>/   arrays.npz (one entry per flattened leaf path)
                           meta.json  {step, names, data_state}
         <dir>/step_<N>.done          (atomic commit marker)

On a real multi-host fleet each host writes only the shards it owns (the
leaf-path file naming already supports per-shard suffixes); on this single-
host substrate leaves are written whole.  Restore validates the commit marker
so a half-written checkpoint from a killed run is never loaded — that plus
resume-latest gives crash-consistent restart.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


class _Waiter:
    """Handle for an async checkpoint write.

    ``join()`` blocks until the writer finishes and *re-raises* any failure,
    so a crashed background write can never be silently mistaken for a
    committed checkpoint — the caller that joins (the trainer, before
    starting the next writer or returning) fails loudly instead.  The commit
    marker is only written after a fully successful write, so even an
    unjoined crash leaves the previous committed step as restore target.
    """

    def __init__(self, target):
        self._exc: BaseException | None = None

        def _run():
            try:
                target()
            except BaseException as e:   # re-raised at join()
                self._exc = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def join(self, timeout: float | None = None) -> None:
        self._thread.join(timeout)
        if self._exc is not None:
            raise self._exc

    def is_alive(self) -> bool:
        return self._thread.is_alive()


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out[name] = np.asarray(leaf)
    return out


def save(ckpt_dir: str, state, step: int, data_state: dict | None = None,
         keep: int = 3, async_write: bool = False):
    """Write checkpoint for `step`.  Returns the (possibly async) waiter."""
    arrays = _flatten(state)

    def _write():
        os.makedirs(ckpt_dir, exist_ok=True)
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
        final = os.path.join(ckpt_dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "names": sorted(arrays),
                       "data_state": data_state or {}}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        open(final + ".done", "w").close()
        _gc(ckpt_dir, keep)

    if async_write:
        return _Waiter(_write)
    _write()
    return None


def _gc(ckpt_dir: str, keep: int):
    """Prune to the newest ``keep`` *committed* steps (``keep=0`` keeps all).
    Operating on ``available_steps`` means the newest committed step is
    always in the survivor slice, and half-written (uncommitted) dirs are
    never touched — they stay invisible to restore either way."""
    steps = sorted(available_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
        try:
            os.remove(os.path.join(ckpt_dir, f"step_{s}.done"))
        except OSError:
            pass


def available_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for n in os.listdir(ckpt_dir):
        if n.startswith("step_") and not n.endswith(".done"):
            if os.path.exists(os.path.join(ckpt_dir, n + ".done")):
                out.append(int(n.split("_")[1]))
    return sorted(out)


def restore(ckpt_dir: str, like, step: int | None = None,
            shardings=None):
    """Restore the latest (or given) committed step into the structure of
    `like`.  With `shardings`, leaves are device_put with the target sharding
    — this is also the elastic-rescale path: a checkpoint written on one mesh
    restores onto any other mesh.  Returns (state, step, data_state)."""
    steps = available_steps(ckpt_dir)
    if not steps:
        return None, -1, {}
    step = steps[-1] if step is None else step
    d = os.path.join(ckpt_dir, f"step_{step}")
    z = np.load(os.path.join(d, "arrays.npz"))
    meta = json.load(open(os.path.join(d, "meta.json")))

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    sh_flat = (jax.tree.leaves(shardings) if shardings is not None
               else [None] * len(flat))
    leaves = []
    for (path, leaf), sh in zip(flat, sh_flat):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = z[name]
        assert arr.shape == tuple(leaf.shape), (name, arr.shape, leaf.shape)
        arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    return state, step, meta.get("data_state", {})
