"""Training loop with cluster-level fault tolerance.

- step-atomic checkpoints (async write) + resume-from-latest with data state
- straggler mitigation: steps slower than `straggler_factor` x the running
  median are logged and counted; past `straggler_patience` consecutive slow
  steps the trainer requests a checkpoint so a reschedule loses nothing
  (on CPU CI this is exercised via an injected delay hook)
- elastic re-mesh: on simulated node loss, rebuild the mesh from survivors
  and restore the state onto the new shardings (see repro.train.elastic)
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax

from repro.data.pipeline import DataConfig, LMIterator
from repro.optim import AdamWConfig
from repro.train import checkpoint as ckpt
from repro.train.train_step import init_state, make_train_step, state_shardings


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_async: bool = True
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    straggler_patience: int = 3
    seed: int = 0


class Trainer:
    def __init__(self, model, shape, opt_cfg: AdamWConfig | None = None,
                 cfg: TrainerConfig | None = None, mesh=None,
                 data_cfg: DataConfig | None = None,
                 delay_hook=None):
        self.model, self.shape = model, shape
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.cfg = cfg or TrainerConfig()
        self.mesh = mesh
        self.delay_hook = delay_hook  # tests inject artificial stragglers
        self.data = LMIterator(model.cfg, shape, data_cfg)
        _, self.jit_step = make_train_step(model, self.opt_cfg, mesh=mesh)
        self.metrics_log: list[dict] = []
        self.straggler_events = 0
        self._slow_streak = 0

    # ------------------------------------------------------------ state ---
    def init_or_restore(self):
        like = jax.eval_shape(
            lambda k: init_state(self.model, k, self.opt_cfg),
            jax.random.PRNGKey(self.cfg.seed))
        sh = (state_shardings(like, self.mesh) if self.mesh is not None
              else None)
        state, step, dstate = ckpt.restore(self.cfg.ckpt_dir, like,
                                           shardings=sh)
        if state is None:
            state = init_state(self.model, jax.random.PRNGKey(self.cfg.seed),
                               self.opt_cfg)
            step = 0
        else:
            step = int(step)
            self.data.restore(dstate)
        return state, step

    # ------------------------------------------------------------- loop ---
    def run(self, state=None, start_step: int | None = None):
        if state is None:
            state, start_step = self.init_or_restore()
        step = start_step or 0
        durations: list[float] = []
        waiter = None
        while step < self.cfg.total_steps:
            batch = next(self.data)
            t0 = time.monotonic()
            if self.delay_hook is not None:
                self.delay_hook(step)
            state, metrics = self.jit_step(state, batch)
            loss = float(metrics["loss"])  # blocks; also a health check
            dt = time.monotonic() - t0
            durations.append(dt)
            med = sorted(durations)[len(durations) // 2]
            is_straggler = (len(durations) >= 5
                            and dt > self.cfg.straggler_factor * med)
            if is_straggler:
                self.straggler_events += 1
                self._slow_streak += 1
            else:
                self._slow_streak = 0
            step += 1
            row = {"step": step, "loss": loss, "sec": dt,
                   "straggler": is_straggler,
                   "grad_norm": float(metrics["grad_norm"])}
            self.metrics_log.append(row)
            if step % self.cfg.log_every == 0:
                print(json.dumps(row))
            must_ckpt = (step % self.cfg.ckpt_every == 0
                         or step == self.cfg.total_steps
                         or self._slow_streak >= self.cfg.straggler_patience)
            if must_ckpt:
                if waiter is not None:
                    waiter.join()
                waiter = ckpt.save(self.cfg.ckpt_dir, state, step,
                                   data_state=self.data.state(),
                                   keep=self.cfg.keep,
                                   async_write=self.cfg.ckpt_async)
                self._slow_streak = 0
        if waiter is not None:
            waiter.join()
        return state, step

    def save_metrics(self, path: str):
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            for row in self.metrics_log:
                f.write(json.dumps(row) + "\n")
