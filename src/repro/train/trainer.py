"""Training loop with cluster-level fault tolerance.

- step-atomic checkpoints (async write) + resume-from-latest with data state
- fault-aware training (FAT): ``TrainerConfig.fat_policy`` threads the
  ``repro.ft`` protection stack through the forward pass so the network
  trains through injected faults (per-step/per-microbatch key streams are
  folded from the restored step counter inside the jitted step, so a resumed
  run continues the exact fault stream — see docs/training.md)
- straggler mitigation: steps slower than `straggler_factor` x the median of
  a bounded window of recent step times are logged and counted; past
  `straggler_patience` consecutive slow steps the trainer requests a
  checkpoint so a reschedule loses nothing.  The first step of every run
  (the compile step) is excluded from the window
  (on CPU CI this is exercised via an injected delay hook)
- elastic re-mesh: on (simulated) node loss, ``handle_device_loss`` closes
  the loop — plan the rescale, rebuild the mesh from survivors, scale
  grad_accum to preserve the global batch, restore the latest committed
  checkpoint onto the new shardings, and hand back (state, step) so ``run``
  continues (see repro.train.elastic)
"""
from __future__ import annotations

import bisect
import collections
import dataclasses
import json
import os
import time

import jax

from repro.data.pipeline import DataConfig, LMIterator
from repro.optim import AdamWConfig
from repro.train import checkpoint as ckpt
from repro.train.train_step import init_state, make_train_step, state_shardings


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_async: bool = True
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    straggler_patience: int = 3
    straggler_window: int = 64   # step-time samples the median is taken over
    seed: int = 0
    # ---- fault-aware training (FAT) schedule ----
    fat_policy: str | None = None   # registry policy name (None = clean)
    fat_ber: float = 0.0            # target training BER at end of ramp
    fat_ramp: int = 0               # linear 0 -> fat_ber over this many steps
    fat_seed: int = 17              # root of the training fault-key stream


class _RunningMedian:
    """Median over a bounded window of recent samples.

    A deque tracks arrival order, a sorted list tracks rank order; adding a
    sample is one ``insort`` plus (once full) one ``bisect`` removal —
    O(window) bounded work per step instead of re-sorting the entire run
    history (O(n log n) *per step*, O(n^2 log n) over a long run)."""

    def __init__(self, window: int):
        self.window = max(int(window), 1)
        self._fifo: collections.deque = collections.deque()
        self._sorted: list[float] = []

    def add(self, x: float) -> None:
        self._fifo.append(x)
        bisect.insort(self._sorted, x)
        if len(self._fifo) > self.window:
            old = self._fifo.popleft()
            del self._sorted[bisect.bisect_left(self._sorted, old)]

    def __len__(self) -> int:
        return len(self._fifo)

    @property
    def median(self) -> float:
        return self._sorted[len(self._sorted) // 2]


class Trainer:
    def __init__(self, model, shape, opt_cfg: AdamWConfig | None = None,
                 cfg: TrainerConfig | None = None, mesh=None,
                 data_cfg: DataConfig | None = None,
                 delay_hook=None):
        self.model, self.shape = model, shape
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.cfg = cfg or TrainerConfig()
        self.mesh = mesh
        self.delay_hook = delay_hook  # tests inject artificial stragglers
        self.data = LMIterator(model.cfg, shape, data_cfg)
        self._build_step()
        self.metrics_log: list[dict] = []
        self.straggler_events = 0
        self._slow_streak = 0

    def _build_step(self):
        c = self.cfg
        fat = {}
        if c.fat_policy is not None:
            fat = dict(policy=c.fat_policy, ft_ber=c.fat_ber,
                       ft_key=jax.random.PRNGKey(c.fat_seed),
                       fat_ramp=c.fat_ramp)
        _, self.jit_step = make_train_step(self.model, self.opt_cfg,
                                           mesh=self.mesh, **fat)

    # ------------------------------------------------------------ state ---
    def _state_like(self):
        return jax.eval_shape(
            lambda k: init_state(self.model, k, self.opt_cfg),
            jax.random.PRNGKey(self.cfg.seed))

    def init_or_restore(self):
        like = self._state_like()
        sh = (state_shardings(like, self.mesh) if self.mesh is not None
              else None)
        state, step, dstate = ckpt.restore(self.cfg.ckpt_dir, like,
                                           shardings=sh)
        if state is None:
            state = init_state(self.model, jax.random.PRNGKey(self.cfg.seed),
                               self.opt_cfg)
            step = 0
        else:
            step = int(step)
            self.data.restore(dstate)
        return state, step

    # ---------------------------------------------------------- elastic ---
    def handle_device_loss(self, surviving_devices):
        """Close the elastic loop after losing devices: plan -> re-mesh ->
        restore-from-latest -> ready to continue.

        ``surviving_devices`` is the list of live devices (or their count —
        the first N of the old mesh are then assumed alive).  The global
        batch is preserved by scaling ``grad_accum`` by the plan's factor;
        the step function is rebuilt for the new mesh (same FAT schedule —
        the restored step counter keeps the fault stream on its coordinate).
        Returns ``(state, step)`` for :meth:`run`.
        """
        from repro.train import elastic

        if self.mesh is None:
            raise ValueError("elastic rescale needs a mesh-backed trainer")
        devices = (list(surviving_devices)
                   if not isinstance(surviving_devices, int)
                   else list(self.mesh.devices.flat)[:surviving_devices])
        model_axis = self.mesh.shape.get("model", 1)
        plan = elastic.plan_rescale(self.mesh, len(devices), model_axis)
        self.mesh = elastic.survivor_mesh(plan, model_axis, devices)
        if plan.grad_accum_scale != 1:
            run2 = dataclasses.replace(
                self.model.run,
                grad_accum=self.model.run.grad_accum * plan.grad_accum_scale)
            self.model = dataclasses.replace(self.model, run=run2)
        self._build_step()
        state, step, dstate, _ = elastic.remesh_restore(
            self.cfg.ckpt_dir, self._state_like(), self.mesh)
        self.data.restore(dstate)
        return state, int(step)

    # ------------------------------------------------------------- loop ---
    def run(self, state=None, start_step: int | None = None):
        if state is None:
            state, start_step = self.init_or_restore()
        step = start_step or 0
        med = _RunningMedian(self.cfg.straggler_window)
        compile_step = True   # first step per run() pays compilation
        waiter = None
        while step < self.cfg.total_steps:
            batch = next(self.data)
            t0 = time.monotonic()
            if self.delay_hook is not None:
                self.delay_hook(step)
            state, metrics = self.jit_step(state, batch)
            loss = float(metrics["loss"])  # blocks; also a health check
            dt = time.monotonic() - t0
            is_straggler = (not compile_step and len(med) >= 5
                            and dt > self.cfg.straggler_factor * med.median)
            if compile_step:
                compile_step = False   # compile time never enters the window
            else:
                med.add(dt)
            if is_straggler:
                self.straggler_events += 1
                self._slow_streak += 1
            else:
                self._slow_streak = 0
            step += 1
            row = {"step": step, "loss": loss, "sec": dt,
                   "straggler": is_straggler,
                   "grad_norm": float(metrics["grad_norm"])}
            if "fat_ber" in metrics:
                row["fat_ber"] = float(metrics["fat_ber"])
            self.metrics_log.append(row)
            if step % self.cfg.log_every == 0:
                print(json.dumps(row))
            must_ckpt = (step % self.cfg.ckpt_every == 0
                         or step == self.cfg.total_steps
                         or self._slow_streak >= self.cfg.straggler_patience)
            if must_ckpt:
                if waiter is not None:
                    waiter.join()   # serialize writers: never two in flight
                waiter = ckpt.save(self.cfg.ckpt_dir, state, step,
                                   data_state=self.data.state(),
                                   keep=self.cfg.keep,
                                   async_write=self.cfg.ckpt_async)
                self._slow_streak = 0
        if waiter is not None:
            waiter.join()
        return state, step

    def save_metrics(self, path: str):
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            for row in self.metrics_log:
                f.write(json.dumps(row) + "\n")
