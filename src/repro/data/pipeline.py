"""Deterministic synthetic data pipeline (host-sharded, resumable).

LM stream: each sequence is a repeated random p-gram (p in [4, 16]) with a
small substitution noise rate — perfectly learnable structure (predict the
token one period back), so a ~100M model shows a real loss curve in a few
hundred CPU/TPU steps.  Everything is a pure function of (seed, step, index),
so restart-at-step-N reproduces the exact stream: the checkpoint stores only
{"step": N}.

Vision set (for the paper's CNN benchmarks): class-conditional procedural
images — fixed random class template + Gaussian noise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    noise: float = 0.05
    min_period: int = 4
    max_period: int = 16


def lm_batch(cfg: DataConfig, vocab: int, batch: int, seq: int, step: int,
             process_index: int = 0, process_count: int = 1):
    """Batch of token sequences for global step `step` (host-sharded slice)."""
    assert batch % process_count == 0
    local = batch // process_count
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    key = jax.random.fold_in(key, process_index)
    ks = jax.random.split(key, 4)
    period = jax.random.randint(ks[0], (local, 1), cfg.min_period,
                                cfg.max_period + 1)
    base = jax.random.randint(ks[1], (local, cfg.max_period), 1, vocab)
    idx = jnp.arange(seq)[None, :] % period
    toks = jnp.take_along_axis(base, idx, axis=1)
    noise_mask = jax.random.bernoulli(ks[2], cfg.noise, (local, seq))
    noise_tok = jax.random.randint(ks[3], (local, seq), 1, vocab)
    toks = jnp.where(noise_mask, noise_tok, toks)
    return toks.astype(jnp.int32)


def make_batch(model_cfg, shape, step: int, data_cfg: DataConfig | None = None,
               process_index: int = 0, process_count: int = 1,
               compute_dtype=jnp.bfloat16):
    """Full batch dict for a (ModelConfig, ShapeConfig) cell."""
    d = data_cfg or DataConfig()
    B, S = shape.global_batch, shape.seq_len
    n_front = model_cfg.n_frontend_tokens if model_cfg.frontend == "vision" else 0
    batch = {"tokens": lm_batch(d, model_cfg.vocab, B, S - n_front, step,
                                process_index, process_count)}
    key = jax.random.fold_in(jax.random.PRNGKey(d.seed + 7), step)
    if model_cfg.frontend == "vision":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (B // process_count, n_front, model_cfg.d_model),
            compute_dtype)
    if model_cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (B // process_count, S, model_cfg.d_model), compute_dtype)
    return batch


# ------------------------------------------------------------- vision ------
def vision_batch(key, n: int, n_classes: int = 8, hw: int = 16,
                 noise: float = 0.4, seed: int = 99):
    """Procedural image classification batch: (images (n,hw,hw,1), labels)."""
    tmpl_key = jax.random.PRNGKey(seed)
    templates = jax.random.normal(tmpl_key, (n_classes, hw, hw, 1))
    k1, k2 = jax.random.split(key)
    labels = jax.random.randint(k1, (n,), 0, n_classes)
    imgs = templates[labels] + noise * jax.random.normal(k2, (n, hw, hw, 1))
    return imgs.astype(jnp.float32), labels


class LMIterator:
    """Stateful, checkpointable iterator facade over the pure batch fn."""

    def __init__(self, model_cfg, shape, data_cfg: DataConfig | None = None,
                 start_step: int = 0):
        self.model_cfg, self.shape = model_cfg, shape
        self.data_cfg = data_cfg or DataConfig()
        self.step = start_step

    def __next__(self):
        b = make_batch(self.model_cfg, self.shape, self.step, self.data_cfg)
        self.step += 1
        return b

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict):
        self.step = int(state.get("step", 0))
