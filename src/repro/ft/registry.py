"""String-keyed protection-policy registry.

All seven comparison designs of the paper (Sec. IV) are registered here at
import time; new designs (automated-design sweeps, fault-aware-training
schedules, ...) plug in with one ``register_policy`` call and are immediately
visible to the accuracy, area, perf and IO oracles — no more editing three
modules per design.
"""
from __future__ import annotations

from repro.ft.policy import (AlgorithmLayer, ArchLayer, CircuitLayer,
                             ProtectionPolicy)

_REGISTRY: dict[str, ProtectionPolicy] = {}


def register_policy(policy: ProtectionPolicy, *, name: str | None = None,
                    overwrite: bool = False) -> ProtectionPolicy:
    """Register ``policy`` under ``name`` (default: ``policy.name``)."""
    key = name or policy.name
    if not key:
        raise ValueError("policy needs a non-empty name to be registered")
    if key in _REGISTRY and not overwrite:
        raise ValueError(f"policy {key!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[key] = policy
    return policy


def get_policy(name: str, **tune) -> ProtectionPolicy:
    """Look up a registered policy; keyword overrides are routed through
    :meth:`ProtectionPolicy.tune` (e.g. ``get_policy("cl", ber=1e-3,
    ib_th=4)``)."""
    try:
        policy = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown protection policy {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None
    return policy.tune(**tune) if tune else policy


def list_policies() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def paper_policies(cl: ProtectionPolicy | None = None) -> dict[str, ProtectionPolicy]:
    """The paper's comparison set; ``cl`` optionally replaces the TMR-CL
    entry with a DSE-optimized instance."""
    out = {n: get_policy(n) for n in
           ("base", "crt1", "crt2", "crt3", "arch", "alg", "cl")}
    if cl is not None:
        out["cl"] = cl
    return out


def _register_paper_designs() -> None:
    # Unprotected baseline: plain quantized datapath, no redundancy anywhere.
    register_policy(ProtectionPolicy(
        name="base",
        algorithm=AlgorithmLayer(q_scale=0),
        circuit=CircuitLayer(ib_th=0, nb_th=0)))
    # Circuit-only TMR: every PE protects its top-k output bits, importance-
    # blind (ib == nb), direct (non-configurable) protection wiring.
    for k in (1, 2, 3):
        register_policy(ProtectionPolicy(
            name=f"crt{k}",
            algorithm=AlgorithmLayer(q_scale=0),
            circuit=CircuitLayer(ib_th=k, nb_th=k, pe_policy="direct")))
    # Architecture-only: spatial TMR of the sensitive layers (array split in
    # three voting replicas).
    register_policy(ProtectionPolicy(
        name="arch",
        algorithm=AlgorithmLayer(q_scale=0),
        arch=ArchLayer(whole_layer_tmr=True, temporal=False)))
    # Algorithm-only: temporal TMR of the sensitive layers (3x re-execution).
    register_policy(ProtectionPolicy(
        name="alg",
        algorithm=AlgorithmLayer(q_scale=0),
        arch=ArchLayer(whole_layer_tmr=True, temporal=True)))
    # The paper's cross-layer design: importance-driven DPPU recompute +
    # selective high-bit TMR + Q_scale-constrained quantization.
    register_policy(ProtectionPolicy(
        name="cl",
        algorithm=AlgorithmLayer(s_th=0.05, s_policy="uniform", q_scale=7),
        arch=ArchLayer(recompute=True),
        circuit=CircuitLayer(ib_th=2, nb_th=1, pe_policy="configurable")))


_register_paper_designs()
