"""Bridging the legacy ``FTConfig`` vector onto protection policies.

``FTConfig`` remains the flat Table-I design vector used by checkpointsed
experiment configs; everything downstream of the public API now speaks
:class:`~repro.ft.policy.ProtectionPolicy`.
"""
from __future__ import annotations

from repro.ft.policy import ProtectionPolicy
from repro.ft.registry import get_policy


def from_ftconfig(cfg) -> ProtectionPolicy:
    """Convert a legacy ``repro.core.flexhyca.FTConfig`` (duck-typed: any
    object with its fields) into the equivalent registered policy.

    Only the fields the named design actually consumes are carried over —
    e.g. a ``crt2`` config's ``q_scale``/``ib_th`` were always inert (the
    protected-bit count comes from the design name), and remain so.
    """
    base = get_policy(cfg.strategy)
    over = dict(ber=cfg.ber, weight_faults=cfg.weight_faults, seed=cfg.seed,
                dot_size=cfg.dot_size, data_reuse=cfg.data_reuse)
    if base.uses_importance:  # the cross-layer design: full tunable surface
        over.update(s_th=cfg.s_th, s_policy=cfg.s_policy, q_scale=cfg.q_scale,
                    ib_th=cfg.ib_th, nb_th=cfg.nb_th, pe_policy=cfg.pe_policy)
    return base.tune(**over)


def as_policy(ft) -> ProtectionPolicy | None:
    """Normalize None | policy name | FTConfig | ProtectionPolicy."""
    if ft is None or isinstance(ft, ProtectionPolicy):
        return ft
    if isinstance(ft, str):
        return get_policy(ft)
    return from_ftconfig(ft)
