"""``repro.ft`` — the public fault-tolerance API.

One protection vocabulary for serving, training, DSE and benchmarks:

    from repro import ft

    policy = ft.get_policy("cl", ber=1e-3, ib_th=4)       # registry lookup
    y = ft.protect_linear(key, x, w, policy, important=m) # reference backend
    y = ft.protect_linear(key, x, w, policy, important=m,
                          backend="pallas")               # fused TPU kernel

Policies are frozen-dataclass pytrees whose only dynamic leaf is ``ber``:

    pols = policy.with_ber(jnp.logspace(-5, -2, 16))
    ys = jax.vmap(lambda p: ft.protect_linear(key, x, w, p))(pols)
"""
# Import order matters: policy/registry/compat must be bound before api —
# api pulls in repro.core, whose package __init__ imports back from repro.ft.
from repro.ft.policy import (AlgorithmLayer, ArchLayer,  # noqa: F401
                             CircuitLayer, ProtectionPolicy)
from repro.ft.registry import (get_policy, list_policies,  # noqa: F401
                               paper_policies, register_policy)
# compat and api must import after policy/registry are bound (see above)
# isort: split
from repro.ft.compat import as_policy, from_ftconfig  # noqa: F401
# isort: split
from repro.ft.api import (BACKENDS, calibrate_t, protect_linear,  # noqa: F401
                          protect_linear_ste)
