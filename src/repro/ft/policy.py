"""Protection policies: the cross-layer fault-tolerance vocabulary.

A ``ProtectionPolicy`` bundles the paper's three layers into one object:

  * :class:`AlgorithmLayer`  — importance selection (Algorithm 1) and the
    Q_scale quantization constraint,
  * :class:`ArchLayer`       — DPPU recompute-and-select and whole-layer
    spatial/temporal TMR, plus the DPPU/dataflow knobs the perf model reads,
  * :class:`CircuitLayer`    — per-channel high-bit TMR (IB_TH / NB_TH) and
    the PE protection wiring policy.

Policies are frozen dataclasses registered as JAX pytrees with ``ber`` as the
single dynamic leaf: everything structural is static metadata (so the jitted
compute path specializes on it), while the bit-error rate traces.  That makes
BER sweeps a ``vmap``/``scan`` over one compiled executable instead of one
re-jit per operating point:

    pols = get_policy("cl").with_ber(jnp.logspace(-5, -2, 16))
    accs = jax.vmap(lambda p: protect_linear(key, x, w, p))(pols)
"""
from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class AlgorithmLayer:
    """Algorithm-layer knobs (paper Sec. III-A): neuron-importance selection
    and the quantization (Q_scale) constraint on the accumulator window."""
    s_th: float = 0.05        # fraction of output channels deemed important
    s_policy: str = "uniform"  # importance selection policy (Algorithm 1)
    q_scale: int = 0          # minimum truncation LSB; 0 = unconstrained


@dataclasses.dataclass(frozen=True)
class ArchLayer:
    """Architecture-layer knobs (paper Sec. III-B): how redundancy is laid
    out across the compute fabric."""
    recompute: bool = False        # DPPU recompute-and-select (FlexHyCA)
    whole_layer_tmr: bool = False  # full-layer TMR of protected layers
    temporal: bool = False         # TMR in time (ALG) vs space (ARCH)
    dot_size: int = 52             # DPPU MAC count
    data_reuse: bool = True        # DPPU reads activations from the array cache


@dataclasses.dataclass(frozen=True)
class CircuitLayer:
    """Circuit-layer knobs (paper Sec. III-D): per-channel high-bit TMR."""
    ib_th: int = 0            # protected high bits of important channels
    nb_th: int = 0            # protected high bits of ordinary channels
    pe_policy: str = "configurable"  # PE protection wiring: configurable|direct


# Fields routed by ProtectionPolicy.tune() to each component.
_ALG_FIELDS = frozenset(f.name for f in dataclasses.fields(AlgorithmLayer))
_ARCH_FIELDS = frozenset(f.name for f in dataclasses.fields(ArchLayer))
_CIRCUIT_FIELDS = frozenset(f.name for f in dataclasses.fields(CircuitLayer))


@dataclasses.dataclass(frozen=True)
class ProtectionPolicy:
    """One complete cross-layer protection design.

    ``ber`` is the only pytree leaf — batch it (``with_ber(jnp.array([...]))``)
    and ``vmap`` to sweep operating points without recompiling.  All other
    fields are static metadata that the compute path specializes on.
    """
    name: str
    algorithm: AlgorithmLayer = AlgorithmLayer()
    arch: ArchLayer = ArchLayer()
    circuit: CircuitLayer = CircuitLayer()
    ber: float = 0.0
    weight_faults: bool = True
    seed: int = 0

    # -------------------------------------------------------- derivation --
    @property
    def perf_kind(self) -> str:
        """The perf/IO-model family this policy belongs to, derived from the
        layer structure (this used to be a name->kind dict duplicated across
        modules)."""
        if self.arch.whole_layer_tmr:
            return "alg" if self.arch.temporal else "arch"
        if self.arch.recompute:
            return "cl"
        if self.circuit.ib_th > 0 or self.circuit.nb_th > 0:
            return "crt"
        return "base"

    @property
    def uses_importance(self) -> bool:
        """Whether this policy consumes Algorithm-1 importance masks."""
        return self.arch.recompute

    # ------------------------------------------------------------- tuning --
    def tune(self, **overrides) -> "ProtectionPolicy":
        """Return a copy with fields replaced, routing each name to the
        component that owns it (``ib_th`` -> circuit, ``s_th`` -> algorithm,
        ``dot_size`` -> arch, ``ber``/``weight_faults``/``seed``/``name`` ->
        the policy itself)."""
        alg, arch, circ, top = {}, {}, {}, {}
        for k, v in overrides.items():
            if k in _ALG_FIELDS:
                alg[k] = v
            elif k in _ARCH_FIELDS:
                arch[k] = v
            elif k in _CIRCUIT_FIELDS:
                circ[k] = v
            elif k in ("ber", "weight_faults", "seed", "name"):
                top[k] = v
            else:
                raise TypeError(f"unknown protection-policy field: {k!r}")
        if alg:
            top["algorithm"] = dataclasses.replace(self.algorithm, **alg)
        if arch:
            top["arch"] = dataclasses.replace(self.arch, **arch)
        if circ:
            top["circuit"] = dataclasses.replace(self.circuit, **circ)
        return dataclasses.replace(self, **top)

    def with_ber(self, ber) -> "ProtectionPolicy":
        """Copy with a new BER; accepts an array for vmap/scan sweeps."""
        return dataclasses.replace(self, ber=ber)


jax.tree_util.register_dataclass(
    ProtectionPolicy,
    data_fields=["ber"],
    meta_fields=["name", "algorithm", "arch", "circuit", "weight_faults",
                 "seed"],
)
