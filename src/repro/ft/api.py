"""``protect_linear`` — the single fault-tolerant linear entry point.

Two backends compute the same FlexHyCA semantics:

  * ``backend="reference"`` — the bit-exact functional model (the former
    ``repro.core.flexhyca.ft_linear`` math), jitted with the policy's
    structure static and its BER traced, so BER sweeps vmap/scan over one
    executable.
  * ``backend="pallas"`` — the fused TPU kernel
    (``repro.kernels.protected_mm``): int8 MXU matmul, 24-bit saturating
    accumulate, Q_scale-constrained truncation and selective bit protection
    in the epilogue of the same tile pass.  The truncation LSB ``t`` is
    per-layer deployment state on the DLA; it is calibrated from the inputs
    when not supplied, so this backend needs concrete (non-traced) operands.
    The kernel models ECC-protected weight SRAM, so ``policy.weight_faults``
    does not apply on this path.

  * ``backend="fused"`` — the fused decode kernel
    (``repro.kernels.fused_decode``): the *same* key schedule and fault
    draws as the reference backend, packed into int32 flip words and
    consumed by one Pallas pass (matmul + saturate + in-kernel truncation
    LSB + XOR + DPPU select).  Bit-identical to ``reference`` for every
    registry policy — global or (M, 2) per-row keys, weight faults
    included (per-row weight faults give each batch row an independent
    faulty-weight view), traced ``dyn`` overrides supported.  This is the
    serving hot-path backend; see ``docs/kernels.md``.

Reference and fused agree bit-exactly at any BER; pallas agrees at BER 0
and draws from an independent RNG stream otherwise (it uses pre-generated
uint32 planes rather than the packed flip words).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import faults
from repro.core import quantization as Q
from repro.ft.policy import ProtectionPolicy

BACKENDS = ("reference", "fused", "pallas")


def calibrate_t(x, w, q_scale: int = 0) -> int:
    """Pick a layer's truncation LSB from calibration data — deployment
    state for the pallas backend (whose kernel takes ``t`` statically)."""
    from repro.kernels.protected_mm.ops import calibrate_t as _calibrate
    return _calibrate(x, w, q_scale=q_scale)


def protect_linear(key: jax.Array, x: jax.Array, w: jax.Array,
                   policy: ProtectionPolicy,
                   important: jax.Array | None = None, *,
                   layer_protected: bool = True,
                   backend: str = "reference",
                   t: int | None = None,
                   interpret: bool = True,
                   dyn=None) -> jax.Array:
    """Fault-tolerant linear: float in/out, faulty quantized DLA inside.

    Args:
      key: one PRNG key, or an (M, 2) batch of keys — one per row of the
        flattened x — for *per-row* independent fault streams (and per-row
        quantization scales), so a serving batch's reliability accounting
        stays per-request.  Per-row mode is supported by the reference and
        fused backends; with ``policy.weight_faults`` each row additionally
        sees its own independently drawn faulty-weight view.
      x: (..., K) activations.  w: (K, N) weights.
      policy: a :class:`ProtectionPolicy` (see ``repro.ft.get_policy``).
      important: (N,) bool mask of important output channels (Algorithm 1);
        consumed only by recompute policies.
      layer_protected: for whole-layer-TMR policies (arch/alg) — whether this
        layer is in the protected (sensitive) set.
      backend: "reference" | "fused" | "pallas".
      t: truncation LSB for the pallas backend (calibrated from x/w if None).
      interpret: run the pallas/fused kernel in interpret mode (CPU).
      dyn: optional mapping of *traced* overrides for the policy's numeric
        protection knobs (``ib_th`` / ``nb_th`` / ``q_scale``).  The static
        values in ``policy`` are metadata the executable specializes on; a
        ``dyn`` entry moves that knob onto the trace so a batch of candidate
        designs with different knob values shares one compiled executable
        (the batched DSE oracle — see ``repro.core.evaluate``).  Supported
        by the reference and fused backends (the fused kernel takes
        ``q_scale`` as a scalar operand and folds ``ib_th``/``nb_th`` into
        the flip-word draws).
    Returns (..., N) float32.
    """
    if backend == "reference":
        return _protect_reference(key, x, w, policy, important,
                                  layer_protected, dyn)
    if backend == "fused":
        from repro.kernels.fused_decode.ops import fused_protect_linear
        return fused_protect_linear(key, x, w, policy, important,
                                    layer_protected=layer_protected,
                                    dyn=dyn, interpret=interpret)
    if getattr(key, "ndim", 1) == 2:
        raise ValueError("per-row key batches are only supported by "
                         "backend='reference' or backend='fused'")
    if dyn:
        raise ValueError("dyn knob overrides are only supported by "
                         "backend='reference' or backend='fused' (the "
                         "pallas kernel takes its protection knobs "
                         "statically)")
    if backend == "pallas":
        return _protect_pallas(key, x, w, policy, important,
                               layer_protected=layer_protected, t=t,
                               interpret=interpret)
    raise ValueError(f"unknown backend {backend!r}; expected one of "
                     f"{BACKENDS}")


# ------------------------------------------------------ straight-through ----
@jax.custom_vjp
def _ste_tie(x, w, y_prot):
    """Forward: the protected output, untouched.  Backward: cotangents of the
    clean float matmul ``x @ w`` — the straight-through estimator."""
    return y_prot


def _ste_fwd(x, w, y_prot):
    return y_prot, (x, w)


def _ste_bwd(res, g):
    x, w = res
    g2 = g.astype(jnp.float32).reshape(-1, w.shape[1])
    x2 = x.astype(jnp.float32).reshape(-1, w.shape[0])
    gx = (g2 @ w.astype(jnp.float32).T).reshape(x.shape).astype(x.dtype)
    gw = (x2.T @ g2).astype(w.dtype)
    return gx, gw, jnp.zeros_like(g)


_ste_tie.defvjp(_ste_fwd, _ste_bwd)


def protect_linear_ste(key: jax.Array, x: jax.Array, w: jax.Array,
                       policy: ProtectionPolicy,
                       important: jax.Array | None = None, **kw) -> jax.Array:
    """:func:`protect_linear` with a straight-through gradient rule — the
    fault-aware-training (FAT) entry point.

    The forward value is the :func:`protect_linear` output *unchanged* (the
    integer inject/protect/quantize datapath stays bit-exact — the training
    loss sees exactly the faulty DLA the deployment will run), while the
    backward pass returns the cotangents of the clean float ``x @ w``: the
    non-differentiable quantize/flip/truncate chain is treated as identity,
    so gradients flow and the network learns to place its decision margins
    where bit flips cannot reach them.  ``kw`` is forwarded verbatim
    (``layer_protected`` / ``backend`` / ``t`` / ``interpret`` / ``dyn``).
    """
    y = protect_linear(key, jax.lax.stop_gradient(x),
                       jax.lax.stop_gradient(w), policy, important, **kw)
    return _ste_tie(x, w, y)


# ------------------------------------------------------------ reference ----
@partial(jax.jit, static_argnames=("layer_protected",))
def _protect_reference(key, x, w, policy: ProtectionPolicy, important,
                       layer_protected: bool, dyn=None):
    """The former ``ft_linear`` datapath, structure-dispatched on the policy.

    Every fault-injection site executes unconditionally with the (possibly
    traced) BER — at BER 0 each injection is the identity, so the output is
    bit-identical to the branch-skipping legacy code while remaining
    vmap-able over a BER axis.  ``dyn`` optionally replaces the static
    ``ib_th`` / ``nb_th`` / ``q_scale`` metadata with traced values so those
    knobs can ride the same vmap axis (integer datapath => the result stays
    bit-identical to the static trace of the same values).

    An (M, 2) ``key`` batch switches to *per-row* mode: each row gets its
    own activation-quantization scale, truncation LSB and fault draws, so
    row b's output is a function of row b's input and key only — batch
    composition cannot perturb another request's fault stream (the
    continuous-batching scheduler's reliability contract).  With
    ``policy.weight_faults`` that extends to the weights: each row sees the
    shared weight matrix through its own independently drawn flip words, as
    if the DLA re-read a freshly faulted weight SRAM per request.
    """
    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1])
    per_row = getattr(key, "ndim", 1) == 2
    if per_row:
        ks = jax.vmap(lambda k: jax.random.split(k, 3))(key)   # (M, 3, 2)
        kw, ka, kd = ks[:, 0], ks[:, 1], ks[:, 2]
    else:
        kw, ka, kd = jax.random.split(key, 3)
    n = w.shape[1]
    alg, arch, circ = policy.algorithm, policy.arch, policy.circuit
    dyn = dyn or {}
    ib_th = dyn.get("ib_th", circ.ib_th)
    nb_th = dyn.get("nb_th", circ.nb_th)
    q_scale = dyn.get("q_scale", alg.q_scale)

    xq, sx = Q.quantize(x2, axis=1 if per_row else None)
    wq, sw = Q.quantize(w)
    if policy.weight_faults and per_row:
        # each row's private faulty-weight view: (M, 2) kw keys -> (M, K, N)
        # packed flip words applied to the shared weights
        wfl = jax.vmap(lambda k: faults.flip_word(
            k, wq.shape, policy.ber, Q.OUT_BITS))(kw)
        uw = (wq[None, :, :] & ((1 << Q.OUT_BITS) - 1)) ^ wfl
        wq_f = jnp.where((uw & (1 << (Q.OUT_BITS - 1))) != 0,
                         uw - (1 << Q.OUT_BITS), uw)
        acc = jax.vmap(lambda a, b: jnp.matmul(
            a, b, preferred_element_type=jnp.int32))(xq, wq_f)
    else:
        wq_f = (faults.inject_weight_faults(kw, wq, policy.ber)
                if policy.weight_faults else wq)
        acc = jnp.matmul(xq, wq_f, preferred_element_type=jnp.int32)
    acc = Q.saturate(acc)
    absmax = (jnp.max(jnp.abs(acc), axis=1, keepdims=True) if per_row
              else jnp.max(jnp.abs(acc)))
    t = Q.choose_trunc_lsb(absmax, q_scale=q_scale)
    yq = Q.truncate_acc(acc, t)

    def inject(keys, yq, protect):
        if per_row:   # independent per-row draws: (M, 2) keys over (M, N)
            return jax.vmap(lambda k, y: faults.inject_output_faults(
                k, y, policy.ber, protect_top=protect))(keys, yq)
        return faults.inject_output_faults(keys, yq, policy.ber,
                                           protect_top=protect)

    # circuit layer: per-channel protected high bits
    imp = jnp.zeros((n,), bool) if important is None else important
    protect = jnp.where(imp, ib_th, nb_th).astype(jnp.int32)
    if arch.whole_layer_tmr and layer_protected:
        # spatial/temporal TMR of the whole layer: every bit voted
        protect = jnp.full((n,), Q.OUT_BITS, jnp.int32)
    yq_f = inject(ka, yq, protect)

    if arch.recompute and important is not None:
        # architecture layer: DPPU recomputes important channels on its own
        # (clean weight SRAM + IB_TH-bit-protected MACs) and overrides.
        acc_d = Q.saturate(jnp.matmul(xq, wq,
                                      preferred_element_type=jnp.int32))
        yq_d = Q.truncate_acc(acc_d, t)
        yq_d = inject(kd, yq_d,
                      jnp.broadcast_to(jnp.asarray(ib_th, jnp.int32), (n,)))
        yq_f = jnp.where(important[None, :], yq_d, yq_f)

    scale = sx * sw * (2.0 ** t.astype(jnp.float32))
    y = yq_f.astype(jnp.float32) * scale
    return y.reshape(*orig_shape[:-1], n)


# --------------------------------------------------------------- pallas ----
def _pad_to(a: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [(0, -s % m) for s, m in zip(a.shape, mults)]
    if any(p for _, p in pads):
        a = jnp.pad(a, pads)
    return a


def _protect_pallas(key, x, w, policy: ProtectionPolicy, important, *,
                    layer_protected: bool, t: int | None, interpret: bool,
                    block: int = 128):
    from repro.kernels.fault_inject.ops import random_planes
    from repro.kernels.protected_mm.kernel import protected_mm

    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1]).astype(jnp.float32)
    w = w.astype(jnp.float32)
    n = w.shape[1]

    xq, sx = Q.quantize(x2)
    wq, sw = Q.quantize(w)
    if t is None:
        if isinstance(x, jax.core.Tracer) or isinstance(w, jax.core.Tracer):
            raise ValueError(
                "backend='pallas' under jit/vmap needs a pre-calibrated "
                "truncation LSB: pass protect_linear(..., t=...) (see "
                "repro.ft.calibrate_t) or use backend='reference'")
        acc = Q.saturate(jnp.matmul(xq, wq,
                                    preferred_element_type=jnp.int32))
        t = int(Q.choose_trunc_lsb(jnp.max(jnp.abs(acc)),
                                   q_scale=policy.algorithm.q_scale))

    circ = policy.circuit
    if policy.arch.whole_layer_tmr:
        ib = nb = Q.OUT_BITS if layer_protected else 0
    else:
        ib, nb = circ.ib_th, circ.nb_th
    if important is None or not policy.uses_importance:
        imp = jnp.zeros((n,), jnp.int32)
    else:
        imp = important.astype(jnp.int32)

    # tile-align all operands (zero padding is exact for the int matmul and
    # sliced away before the rescale)
    xq8 = _pad_to(xq.astype(jnp.int8), (block, block))
    wq8 = _pad_to(wq.astype(jnp.int8), (block, block))
    imp_p = _pad_to(imp, (block,))
    mp, np_ = xq8.shape[0], wq8.shape[1]
    k1, k2 = jax.random.split(key)
    rnd_o = random_planes(k1, (mp, np_))
    rnd_i = random_planes(k2, (mp, np_))

    yq = protected_mm(xq8, wq8, rnd_o, rnd_i, imp_p, t=t,
                      ber=float(policy.ber), ib=ib, nb=nb,
                      bm=block, bn=block, bk=block, interpret=interpret)
    scale = sx * sw * (2.0 ** t)
    y = yq[:x2.shape[0], :n].astype(jnp.float32) * scale
    return y.reshape(*orig_shape[:-1], n)
