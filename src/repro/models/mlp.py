"""Dense (G)LU feed-forward block."""
from __future__ import annotations

import jax

from repro.models.common import activation, dense_init, linear, tag, ac


def init(key, cfg, dtype, d_ff=None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], D, F, dtype),
         "wo": dense_init(ks[1], F, D, dtype)}
    if cfg.glu:
        p["wg"] = dense_init(ks[2], D, F, dtype)
    return p


def apply(p, x, cfg, probe=None, ftc=None, name="mlp"):
    act = activation(cfg.act)
    h = linear(x, p["wi"], ftc=ftc, name=f"{name}/wi")
    if cfg.glu:
        g = linear(x, p["wg"], ftc=ftc, name=f"{name}/wg")
        h = act(h) * g
    else:
        h = act(h)
    h = ac(h, "dp", None, "tp")
    h = tag(probe, f"{name}/hidden", h)
    return linear(h, p["wo"], ftc=ftc, name=f"{name}/wo")
