"""GQA attention: chunked online-softmax (flash-style, pure JAX so it lowers
on any backend), local/SWA windows, softcaps, rolling KV caches.

Memory discipline: never materializes an (S x S) score tensor — the kv loop
runs as a fori_loop with O(block^2) live scores, which is what lets 32k
prefill compile inside a v5e HBM budget.  Local-attention layers skip kv
blocks outside the window, so SWA costs O(S*W) not O(S^2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, linear, rope, softcap, tag, ac

NEG = -1e30


def init(key, cfg, dtype):
    D, H, KH, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], D, H * Dh, dtype),
        "wk": dense_init(ks[1], D, KH * Dh, dtype),
        "wv": dense_init(ks[2], D, KH * Dh, dtype),
        "wo": dense_init(ks[3], H * Dh, D, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * Dh,), dtype)
        p["bk"] = jnp.zeros((KH * Dh,), dtype)
        p["bv"] = jnp.zeros((KH * Dh,), dtype)
    return p


def _scale(cfg) -> float:
    return cfg.attn_scale or cfg.d_head ** -0.5


def _single_block(q, k, v, *, causal, window, cap, q_off=0, k_valid=None):
    """Full-score path for short sequences (smoke tests, per-block math)."""
    B, S, KH, G, Dh = q.shape
    T = k.shape[1]
    s = jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    s = softcap(s, cap)
    pq = q_off + jnp.arange(S)[:, None]
    pk = jnp.arange(T)[None, :]
    m = jnp.ones((S, T), bool)
    if causal:
        m &= pq >= pk
    if window:
        m &= pq - pk < window
    if k_valid is not None:
        m &= k_valid[None, :]
    s = jnp.where(m[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))


def chunked_attention(q, k, v, *, causal=True, window=0, cap=0.0,
                      block=512, differentiable=False):
    """q: (B,S,H,Dh); k,v: (B,T,KH,Dh) -> (B,S,H,Dh) (q assumed pre-scaled).

    Two inner-loop strategies over kv blocks:
      - inference (differentiable=False): fori_loop with *dynamic* bounds —
        skips out-of-causal-range / out-of-window blocks entirely (O(S*W) for
        SWA), but dynamic bounds are not reverse-differentiable.
      - training (differentiable=True): lax.scan over all kv blocks with
        block-level masking.  Baseline cost is the full O(S^2); the flash
        custom-VJP kernel path (see EXPERIMENTS.md §Perf) removes the waste.
    """
    B, S, H, Dh = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    q = q.reshape(B, S, KH, G, Dh)
    if S <= block and T <= block:
        o = _single_block(q, k, v, causal=causal, window=window, cap=cap)
        return o.reshape(B, S, H, Dh).astype(v.dtype)

    assert S % block == 0 and T % block == 0, (S, T, block)
    nq, nk = S // block, T // block
    qb = jnp.moveaxis(q.reshape(B, nq, block, KH, G, Dh), 1, 0)
    kb = k.reshape(B, nk, block, KH, Dh)
    vb = v.reshape(B, nk, block, KH, Dh)
    w_blocks = -(-window // block) if window else nk  # ceil

    def per_q(_, xs):
        i, qi = xs                      # qi: (B, blk, KH, G, Dh)
        qi = qi.astype(jnp.float32)
        acc = jnp.zeros((B, KH, G, block, Dh), jnp.float32)
        m = jnp.full((B, KH, G, block), NEG, jnp.float32)
        den = jnp.zeros((B, KH, G, block), jnp.float32)

        def block_update(j, kj, vj, carry):
            acc, m, den = carry
            s = jnp.einsum("bqkgd,bvkd->bkgqv", qi, kj.astype(jnp.float32))
            s = softcap(s, cap)
            pq = i * block + jnp.arange(block)[:, None]
            pk = j * block + jnp.arange(block)[None, :]
            msk = jnp.ones((block, block), bool)
            if causal:
                msk &= pq >= pk
            if window:
                msk &= pq - pk < window
            s = jnp.where(msk[None, None, None], s, NEG)
            mj = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - mj[..., None])
            corr = jnp.exp(m - mj)
            den2 = den * corr + p.sum(-1)
            acc2 = acc * corr[..., None] + jnp.einsum(
                "bkgqv,bvkd->bkgqd", p, vj.astype(jnp.float32))
            return acc2, mj, den2

        if differentiable:
            def body(carry, xs2):
                j, kj, vj = xs2
                return block_update(j, kj, vj, carry), None
            # remat each kv block: the backward pass recomputes the (blk x
            # blk) score tile instead of saving O(S^2/blk^2) of them
            (acc, m, den), _ = jax.lax.scan(
                jax.checkpoint(body, prevent_cse=False), (acc, m, den),
                (jnp.arange(nk), jnp.moveaxis(kb, 1, 0),
                 jnp.moveaxis(vb, 1, 0)))
        else:
            def body(j, carry):
                kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
                vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
                return block_update(j, kj, vj, carry)
            hi = jnp.minimum(i + 1, nk) if causal else nk
            lo = jnp.maximum(i + 1 - w_blocks, 0) if window else 0
            acc, m, den = jax.lax.fori_loop(lo, hi, body, (acc, m, den))
        o = acc / jnp.maximum(den[..., None], 1e-30)
        return None, jnp.moveaxis(o, 3, 1)   # (B, blk, KH, G, Dh)

    _, o = jax.lax.scan(per_q, None, (jnp.arange(nq), qb))
    o = jnp.moveaxis(o, 0, 1).reshape(B, S, KH, G, Dh)
    return o.reshape(B, S, H, Dh).astype(v.dtype)


def apply(p, x, *, cfg, run, kind, positions, probe=None, ftc=None,
          name="attn", cache=None, mode="train", enc_kv=None):
    """Attention sub-layer.  Returns (out, new_cache).

    modes: train (no cache) | prefill (build cache) | decode (1-token step).
    enc_kv: (k, v) from the encoder for cross-attention (positions=None keys).
    """
    B = x.shape[0]
    D, H, KH, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    window = cfg.window if kind == "L" else 0
    cross = enc_kv is not None

    q = linear(x, p["wq"], p.get("bq"), ftc=ftc, name=f"{name}/wq")
    q = q.reshape(*x.shape[:-1], H, Dh)
    if cross:
        k, v = enc_kv
    else:
        k = linear(x, p["wk"], p.get("bk"), ftc=ftc, name=f"{name}/wk")
        v = linear(x, p["wv"], p.get("bv"), ftc=ftc, name=f"{name}/wv")
        k = k.reshape(*x.shape[:-1], KH, Dh)
        v = v.reshape(*x.shape[:-1], KH, Dh)
        k = rope(k, positions, cfg.rope_theta)
        # head-shard k/v like q: without this the residual stream's
        # sequence sharding propagates into the kv length dim, turning the
        # softmax p@v contraction into a partitioned float sum — a
        # reordered accumulation that is not bitwise partition-invariant
        # (the sharded-serving determinism contract, tests/
        # test_serve_sharded.py)
        k = ac(k, "dp", None, "tp", None)
        v = ac(v, "dp", None, "tp", None)
    if not cross:
        q = rope(q, positions, cfg.rope_theta)
    q = (q * _scale(cfg)).astype(x.dtype)
    q = ac(q, "dp", None, "tp", None)

    new_cache = cache
    if mode == "decode" and not cross and "bt" in cache:
        # paged cache: the slot's logical position maps through the block
        # table to a physical row of the shared block pool.  Rows whose
        # table entry is the trash block (id 0 — evicted/idle slots) write
        # garbage nobody reads; rows with real blocks own them exclusively.
        pool_k, pool_v, bt = cache["k"], cache["v"], cache["bt"]
        P, bs = pool_k.shape[0], pool_k.shape[1]
        eff_cap = bt.shape[1] * bs
        pos = positions[:, 0]                                        # (B,)
        slot = pos % window if window else jnp.minimum(pos, eff_cap - 1)
        fi = bt[jnp.arange(B), slot // bs] * bs + slot % bs          # (B,)
        kp = pool_k.reshape(P * bs, KH, Dh).at[fi].set(k[:, 0])
        vp = pool_v.reshape(P * bs, KH, Dh).at[fi].set(v[:, 0])
        new_cache = {"k": kp.reshape(pool_k.shape),
                     "v": vp.reshape(pool_v.shape), "bt": bt}
        # gather this row's blocks back into slot order and run the same
        # count-masked decode attention as the dense layout (bit-identical:
        # masked tail slots never contribute)
        flat = (bt[:, :, None] * bs
                + jnp.arange(bs)[None, None]).reshape(B, eff_cap)
        # the pool is replicated over DP (global block ids) but the gathered
        # per-row view is batch-major again — constrain it like the dense
        # layout so attention runs DP/TP-sharded
        kc = ac(kp[flat], "dp", None, "tp", None)        # (B, C, KH, Dh)
        vc = ac(vp[flat], "dp", None, "tp", None)
        n_valid = jnp.minimum(pos + 1, window if window else eff_cap)
        o = _decode_attn(q, kc, vc, n_valid, cap=cfg.attn_softcap)
    elif mode == "decode" and not cross:
        # write this token into the (possibly rolling) cache.  positions may
        # differ per batch row (continuous batching: every slot serves its
        # own request), so the write is a per-row dynamic update and the
        # valid-length mask is per-row too.
        cap_len = cache["k"].shape[1]
        pos = positions[:, 0]                                        # (B,)
        slot = pos % cap_len if window else jnp.minimum(pos, cap_len - 1)
        upd = lambda c, u, s: jax.lax.dynamic_update_slice_in_dim(c, u, s, 0)
        kc = jax.vmap(upd)(cache["k"], k, slot)
        vc = jax.vmap(upd)(cache["v"], v, slot)
        new_cache = {"k": kc, "v": vc}
        n_valid = jnp.minimum(pos + 1, cap_len)                      # (B,)
        o = _decode_attn(q, kc, vc, n_valid, cap=cfg.attn_softcap)
    elif mode == "decode" and cross:
        # per-row "cn" counts (continuous batching: each slot's encoder
        # context has its own length) fall back to the full buffer length
        o = _decode_attn(q, cache["ck"], cache["cv"],
                         cache.get("cn", cache["ck"].shape[1]),
                         cap=cfg.attn_softcap)
    else:
        o = chunked_attention(q, k, v, causal=not cross, window=window,
                              cap=cfg.attn_softcap, block=run.attn_block,
                              differentiable=(mode == "train"))
        if mode == "prefill" and not cross:
            new_cache = _build_cache(k, v, window)
    o = ac(o, "dp", None, "tp", None)
    o = tag(probe, f"{name}/out", o)
    y = linear(o.reshape(*x.shape[:-1], H * Dh), p["wo"], ftc=ftc,
               name=f"{name}/wo")
    return y, new_cache


def _decode_attn(q, kc, vc, n_valid, cap=0.0):
    """One-token attention over a cache.  q: (B,1,H,Dh), kc: (B,C,KH,Dh).
    n_valid: scalar or per-row (B,) count of populated cache slots."""
    B, _, H, Dh = q.shape
    KH = kc.shape[2]
    G = H // KH
    qg = q.reshape(B, KH, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bkgd,bckd->bkgc", qg, kc.astype(jnp.float32))
    s = softcap(s, cap)
    n_valid = jnp.reshape(n_valid, (-1, 1))           # () -> (1,1); (B,)->(B,1)
    valid = jnp.arange(kc.shape[1])[None] < n_valid
    s = jnp.where(valid[:, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgc,bckd->bkgd", p, vc.astype(jnp.float32))
    return o.reshape(B, 1, H, Dh).astype(vc.dtype)


def _build_cache(k, v, window):
    """Prefill cache: last `window` tokens for local layers (rolling-buffer
    layout: position p lives at slot p % window), all tokens for global."""
    S = k.shape[1]
    if window and S > window:
        k, v = k[:, -window:], v[:, -window:]
        shift = S % window
        if shift:
            k = jnp.roll(k, shift, axis=1)
            v = jnp.roll(v, shift, axis=1)
    elif window and S < window:
        pad = window - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return {"k": k, "v": v}


def init_paged_cache(cfg, kind, batch, cap_len, block_size, n_blocks, dtype):
    """Paged KV cache for one attention layer: a pool of `n_blocks` physical
    blocks of `block_size` token slots, plus a per-row block table mapping
    logical slots to blocks.  Block 0 is the trash block — every table entry
    starts there, and evicted slots are pointed back at it, so idle rows'
    decode writes land in memory nobody reads.  Rolling (window) layers keep
    the same slot map as the dense layout (position p at slot p % window),
    just block-indexed; their table is window-sized."""
    window = cfg.window if kind == "L" else 0
    cap = window if window else cap_len
    width = -(-cap // block_size)                    # ceil
    shp = (n_blocks, block_size, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype),
            "bt": jnp.zeros((batch, width), jnp.int32)}


def init_cache(cfg, kind, batch, cap_len, dtype):
    # rolling caches are always window-sized: position p lives at slot
    # p % window (matching _build_cache and the decode write), so a shorter
    # capacity would break the slot mapping
    window = cfg.window if kind == "L" else 0
    C = window if window else cap_len
    shp = (batch, C, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
