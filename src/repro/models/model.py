"""Public model API: build a Model from (ModelConfig, RunConfig).

All entry points are pure functions of pytrees, ready for jax.jit with
sharding annotations from repro.parallel.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import attention, ssm, transformer as T
from repro.models.common import dtype_of, rms_norm


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    run: RunConfig = RunConfig()

    # ------------------------------------------------------------ params --
    def init(self, key) -> dict:
        return T.init_params(key, self.cfg, self.run)

    # -------------------------------------------------------------- train --
    def loss(self, params, batch, probe=None, ftc=None):
        cfg, run = self.cfg, self.run
        if ftc is None and run.ft_emu:
            from repro.models.common import EmuCtx
            ftc = EmuCtx(run.ft_emu, run.ft_s_th)
        x, labels, mask, enc_inp = T.assemble_inputs(params, cfg, batch)
        enc_out = None
        if cfg.enc_dec:
            enc_out = T.encode(params, enc_inp, cfg=cfg, run=run,
                               probe=probe, ftc=ftc)
        h, _, aux = T.backbone(params, x, cfg=cfg, run=run, mode="train",
                               probe=probe, ftc=ftc, enc_out=enc_out)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        nll = T.chunked_xent(params, cfg, run, h, labels, mask)
        return nll + aux, {"nll": nll, "aux": aux}

    # ------------------------------------------------------------ prefill --
    def prefill(self, params, batch, max_len: int | None = None, ftc=None,
                last_index=None):
        """Forward over a prompt, building the KV/state caches.  `max_len`
        reserves decode headroom in full-attention caches.  `ftc` routes every
        projection through the fault-tolerant DLA path (repro.ft).
        `last_index`: optional (B,) per-row index of the final *real* prompt
        token — for right-padded (bucketed) prompts the returned logits are
        taken there instead of at the last position.
        Returns (caches, last_token_logits)."""
        cfg, run = self.cfg, self.run
        x, _, _, enc_inp = T.assemble_inputs(params, cfg, batch)
        enc_out = None
        if cfg.enc_dec:
            enc_out = T.encode(params, enc_inp, cfg=cfg, run=run, ftc=ftc)
        h, caches, _ = T.backbone(params, x, cfg=cfg, run=run, mode="prefill",
                                  ftc=ftc, enc_out=enc_out)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        if max_len is not None and caches is not None:
            S = x.shape[1]
            pad = max(max_len - S, 0)
            kinds = T._layer_kinds(cfg)

            def grow(path, leaf):
                # full-attention k/v caches have length S and grow to
                # max_len; rolling (window) and state caches keep their
                # fixed capacity (a rolling cache's slot map is p % window
                # — padding it would corrupt the wrap); cross-attn caches
                # are fixed to the encoder length.  Scan-stacked caches
                # (seg*) carry the length on axis 2 (axis 0 = block stack,
                # axis 1 = batch); unrolled ones on axis 1.
                names = [getattr(k, "key", None) for k in path]
                if "cross" in names:
                    return leaf
                if str(names[0]).startswith("seg"):
                    axis = 2
                    pattern, _ = cfg.segments[int(str(names[0])[3:])]
                    kind = pattern[int(str(names[1])[1:])]
                else:
                    axis = 1
                    kind = kinds[int(str(names[0])[1:])]
                if kind == "L" and cfg.window:
                    return leaf
                if (pad and leaf.ndim > axis and leaf.shape[axis] == S):
                    cfgpad = [(0, 0)] * leaf.ndim
                    cfgpad[axis] = (0, pad)
                    return jnp.pad(leaf, cfgpad)
                return leaf

            caches = jax.tree_util.tree_map_with_path(grow, caches)
        return caches, T.last_logits(params, cfg, h, index=last_index)

    # ------------------------------------------------------------- decode --
    def decode_step(self, params, caches, token, pos, ftc=None):
        """One-token decode.  token: (B,) int32; pos: () int32 shared by the
        batch, or (B,) int32 per-row positions (continuous batching: each
        slot serves a request at its own depth).  Returns (new_caches,
        logits (B, V))."""
        cfg, run = self.cfg, self.run
        B = token.shape[0]
        x = T.embed_tokens(params, cfg, token[:, None])
        pos = jnp.asarray(pos, jnp.int32)
        positions = (pos.reshape(B, 1) if pos.ndim
                     else jnp.broadcast_to(pos, (B, 1)))
        h, new_caches, _ = T.backbone(params, x, cfg=cfg, run=run,
                                      mode="decode", caches=caches,
                                      positions=positions, ftc=ftc)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        return new_caches, T.last_logits(params, cfg, h)

    # -------------------------------------------------------------- specs --
    def init_cache(self, batch: int, seq_len: int, *, paged=None,
                   enc_len: int | None = None):
        """Zero caches sized for decoding at context length seq_len.

        ``paged=(block_size, n_blocks)`` switches attention layers to the
        paged layout (block pool + per-row block table — see
        ``attention.init_paged_cache``); recurrent/SSM state and
        cross-attention buffers stay dense per-slot rows.  ``enc_len`` sizes
        the cross-attention buffers (enc-dec only; defaults to seq_len) and
        adds a per-row ``cn`` valid-length so slots can hold encoder
        contexts of different lengths (passing it opts into per-row
        cross-attention masking — the serving scheduler's layout)."""
        cfg, run = self.cfg, self.run
        dtype = dtype_of(run.compute_dtype)
        e_len = enc_len if enc_len is not None else seq_len

        def layer_cache(kind):
            if kind in ("G", "L"):
                if paged is not None:
                    bs, n_blocks = paged
                    c = attention.init_paged_cache(cfg, kind, batch, seq_len,
                                                   bs, n_blocks, dtype)
                else:
                    c = attention.init_cache(cfg, kind, batch, seq_len, dtype)
                if cfg.enc_dec:
                    cross = {
                        "ck": jnp.zeros((batch, e_len, cfg.n_kv_heads,
                                         cfg.d_head), dtype),
                        "cv": jnp.zeros((batch, e_len, cfg.n_kv_heads,
                                         cfg.d_head), dtype)}
                    if enc_len is not None:
                        cross["cn"] = jnp.zeros((batch,), jnp.int32)
                    return {"attn": c, "cross": cross}
                return {"attn": c}
            if kind == "R":
                return {"rglru": {
                    "h": jnp.zeros((batch, cfg.rglru_width), jnp.float32),
                    "conv": jnp.zeros((batch, cfg.rglru_conv - 1,
                                       cfg.rglru_width), dtype)}}
            if kind == "S":
                d_inner, H = ssm.dims(cfg)
                s = cfg.ssm
                return {"ssd": {
                    "state": jnp.zeros((batch, H, s.head_dim, s.d_state),
                                       jnp.float32),
                    "conv": jnp.zeros((batch, s.conv_width - 1,
                                       d_inner + 2 * s.d_state), dtype)}}
            raise ValueError(kind)

        if cfg.unroll:
            return {f"l{i}": layer_cache(k)
                    for i, k in enumerate(T._layer_kinds(cfg))}
        caches = {}
        for si, (pattern, n_rep) in enumerate(cfg.segments):
            blk = {f"s{j}": layer_cache(kind)
                   for j, kind in enumerate(pattern)}
            caches[f"seg{si}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n_rep,) + x.shape)
                .copy() if hasattr(x, "copy") else x, blk)
        return caches

    def batch_specs(self, shape: ShapeConfig):
        """ShapeDtypeStructs for one input batch of the given shape."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        dtype = dtype_of(self.run.compute_dtype)
        if cfg.frontend == "vision":
            P = cfg.n_frontend_tokens
            return {
                "tokens": jax.ShapeDtypeStruct((B, S - P), jnp.int32),
                "patch_embeds": jax.ShapeDtypeStruct((B, P, cfg.d_model), dtype),
            }
        if cfg.enc_dec:
            return {
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    def cache_specs(self, batch: int, seq_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch, seq_len))

    def param_specs(self, seed: int = 0):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(seed)))


def build(cfg: ModelConfig, run: RunConfig | None = None) -> Model:
    return Model(cfg, run or RunConfig())
