from repro.models.model import Model, build  # noqa: F401
