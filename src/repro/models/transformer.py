"""Decoder LM / encoder-decoder assembly over heterogeneous layer blocks.

Layers are grouped into *super-blocks* (one period of cfg.block_pattern) and
scanned with stacked parameters, so HLO size is O(1) in depth; reduced
configs set cfg.unroll for python-loop layers (needed by the importance probe
and FT instrumentation).  Modes: train | prefill | decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, mlp, moe, rglru, ssm
from repro.models.common import (ac, dtype_of, embed_init, linear, rms_norm,
                                 softcap)

MIXERS = {"G": attention, "L": attention, "E": attention,
          "R": rglru, "S": ssm}


# ------------------------------------------------------------------ init ---
def init_layer(key, cfg, kind, dtype, cross=False):
    ks = jax.random.split(key, 6)
    D = cfg.d_model
    p = {"ln1": jnp.zeros((D,), jnp.float32)}
    if kind in ("G", "L", "E"):
        p["attn"] = attention.init(ks[0], cfg, dtype)
    elif kind == "R":
        p["rglru"] = rglru.init(ks[0], cfg, dtype)
    elif kind == "S":
        p["ssd"] = ssm.init(ks[0], cfg, dtype)
    if cfg.post_norm:
        p["ln1_post"] = jnp.zeros((D,), jnp.float32)
    if cross:
        p["lnx"] = jnp.zeros((D,), jnp.float32)
        p["xattn"] = attention.init(ks[1], cfg, dtype)
    if cfg.d_ff > 0 or cfg.moe is not None:
        p["ln2"] = jnp.zeros((D,), jnp.float32)
        p["ffn"] = (moe.init(ks[2], cfg, dtype) if cfg.moe is not None
                    else mlp.init(ks[2], cfg, dtype))
        if cfg.post_norm:
            p["ln2_post"] = jnp.zeros((D,), jnp.float32)
    return p


def init_params(key, cfg, run):
    dtype = dtype_of(run.param_dtype)
    ks = jax.random.split(key, 8)
    params = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(ks[1], cfg.vocab, cfg.d_model, dtype)
    cross = cfg.enc_dec

    if cfg.unroll:
        layers = {}
        for i, kind in enumerate(_layer_kinds(cfg)):
            layers[f"l{i}"] = init_layer(
                jax.random.fold_in(ks[2], i), cfg, kind, dtype, cross=cross)
        params["layers"] = layers
    else:
        for si, (pattern, n_rep) in enumerate(cfg.segments):
            def one_block(k, pattern=pattern):
                kb = jax.random.split(k, len(pattern))
                return {f"s{j}": init_layer(kb[j], cfg, kind, dtype,
                                            cross=cross)
                        for j, kind in enumerate(pattern)}
            params[f"seg{si}"] = jax.vmap(one_block)(
                jax.random.split(jax.random.fold_in(ks[3], si), n_rep))

    if cfg.enc_dec:
        def enc_block(k):
            return {"s0": init_layer(k, cfg, "E", dtype)}
        if cfg.unroll:
            params["enc_layers"] = {
                f"l{i}": init_layer(jax.random.fold_in(ks[5], i), cfg, "E", dtype)
                for i in range(cfg.n_enc_layers)}
        else:
            params["enc_blocks"] = jax.vmap(enc_block)(
                jax.random.split(ks[5], cfg.n_enc_layers))
        params["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return params


def _layer_kinds(cfg):
    return list(cfg.block_pattern) * cfg.n_blocks + list(cfg.tail)


# ----------------------------------------------------------------- layer ---
def apply_layer(p, x, *, kind, cfg, run, mode="train", cache=None,
                positions=None, probe=None, ftc=None, name="blk",
                enc_out=None):
    """One residual layer.  Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = dict(cache) if isinstance(cache, dict) else {}

    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("G", "L", "E"):
        m, c = attention.apply(
            p["attn"], h, cfg=cfg, run=run, kind=kind,
            positions=positions, probe=probe, ftc=ftc, name=f"{name}/attn",
            cache=None if cache is None else cache.get("attn"), mode=mode)
        if c is not None:
            new_cache["attn"] = c
    elif kind == "R":
        m, c = rglru.apply(p["rglru"], h, cfg=cfg, run=run,
                           positions=positions, probe=probe, ftc=ftc,
                           name=f"{name}/rglru",
                           cache=None if cache is None else cache.get("rglru"),
                           mode=mode)
        if c is not None:
            new_cache["rglru"] = c
    elif kind == "S":
        m, c = ssm.apply(p["ssd"], h, cfg=cfg, run=run, positions=positions,
                         probe=probe, ftc=ftc, name=f"{name}/ssd",
                         cache=None if cache is None else cache.get("ssd"),
                         mode=mode)
        if c is not None:
            new_cache["ssd"] = c
    if cfg.post_norm:
        m = rms_norm(m, p["ln1_post"], cfg.norm_eps)
    # SP: sub-layer outputs reduce-scatter into the sequence-sharded residual
    # domain instead of all-reducing the full activation (train/prefill only;
    # decode has seq=1)
    if mode != "decode":
        m = ac(m, "dp", "tp", None)
        x = ac(x, "dp", "tp", None)
    x = x + m

    has_cross_cache = cache is not None and "cross" in cache
    if "xattn" in p and (enc_out is not None or has_cross_cache):
        h = rms_norm(x, p["lnx"], cfg.norm_eps)
        ek = cache.get("cross") if cache else None
        if ek is None:
            ekv = _cross_kv(p["xattn"], enc_out, cfg, ftc, name)
        else:
            ekv = (ek["ck"], ek["cv"])
        xcache = {"ck": ekv[0], "cv": ekv[1]}
        if ek is not None and "cn" in ek:
            # per-row encoder valid lengths (serving slots) ride along
            xcache["cn"] = ek["cn"]
        m, _ = attention.apply(
            p["xattn"], h, cfg=cfg, run=run, kind="G", positions=positions,
            probe=probe, ftc=ftc, name=f"{name}/xattn",
            cache=xcache if mode == "decode" else None,
            mode=mode, enc_kv=ekv)
        if mode in ("prefill", "decode"):
            new_cache["cross"] = xcache
        x = x + m

    if "ffn" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            f, a = moe.apply(p["ffn"], h, cfg, probe=probe, ftc=ftc,
                             name=f"{name}/moe")
            aux = aux + a
        else:
            f = mlp.apply(p["ffn"], h, cfg, probe=probe, ftc=ftc,
                          name=f"{name}/mlp")
        if cfg.post_norm:
            f = rms_norm(f, p["ln2_post"], cfg.norm_eps)
        if mode != "decode":
            f = ac(f, "dp", "tp", None)
        x = x + f
    return x, (new_cache if new_cache else None), aux


def _cross_kv(pa, enc_out, cfg, ftc, name):
    KH, Dh = cfg.n_kv_heads, cfg.d_head
    k = linear(enc_out, pa["wk"], pa.get("bk"), ftc=ftc, name=f"{name}/xk")
    v = linear(enc_out, pa["wv"], pa.get("bv"), ftc=ftc, name=f"{name}/xv")
    return (k.reshape(*enc_out.shape[:-1], KH, Dh),
            v.reshape(*enc_out.shape[:-1], KH, Dh))


# -------------------------------------------------------------- backbone ---
def backbone(params, x, *, cfg, run, mode="train", caches=None,
             positions=None, probe=None, ftc=None, enc_out=None):
    """Apply all layers.  Returns (hidden, new_caches, aux_loss_sum)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.unroll:
        kinds = _layer_kinds(cfg)
        new_caches = {}
        for i, kind in enumerate(kinds):
            c = None if caches is None else caches.get(f"l{i}")
            x, nc, aux = apply_layer(
                params["layers"][f"l{i}"], x, kind=kind, cfg=cfg, run=run,
                mode=mode, cache=c, positions=positions, probe=probe,
                ftc=ftc, name=f"l{i}", enc_out=enc_out)
            if nc is not None:
                new_caches[f"l{i}"] = nc
            aux_total += aux
        return x, (new_caches or None), aux_total

    # scanned super-block segments
    new_caches: dict | None = None
    for si, (pattern, _n) in enumerate(cfg.segments):
        def sb(carry, inp, pattern=pattern):
            x, aux = carry
            # sequence-parallel residual boundary: the per-block saved
            # residual (stacked by scan for the backward pass) shards over
            # BOTH the data axes (batch) and 'model' (sequence) — 16x less
            # residual memory, and the TP all-reduce decomposes into
            # all-gather + reduce-scatter at identical wire cost (Megatron-SP)
            x = ac(x, "dp", "tp", None)
            blk_p = inp[0]
            blk_c = inp[1] if len(inp) > 1 else None
            new_c = {}
            for j, kind in enumerate(pattern):
                c = None if blk_c is None else blk_c.get(f"s{j}")
                x, nc, a = apply_layer(
                    blk_p[f"s{j}"], x, kind=kind, cfg=cfg, run=run, mode=mode,
                    cache=c, positions=positions, probe=probe, ftc=ftc,
                    name=f"sb{si}/s{j}", enc_out=enc_out)
                aux = aux + a
                if nc is not None:
                    new_c[f"s{j}"] = nc
            return (x, aux), (new_c if new_c else None)

        body = sb
        if run.remat == "block":
            body = jax.checkpoint(sb, prevent_cse=False)
        xs = ((params[f"seg{si}"],) if caches is None else
              (params[f"seg{si}"], caches[f"seg{si}"]))
        (x, aux_total), seg_caches = jax.lax.scan(body, (x, aux_total), xs)
        if seg_caches is not None:
            new_caches = dict(new_caches or {})
            new_caches[f"seg{si}"] = seg_caches
    return x, new_caches, aux_total


def encode(params, frames, *, cfg, run, probe=None, ftc=None):
    """Encoder stack over precomputed frontend frame embeddings."""
    x = ac(frames, "dp", None, None)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.unroll:
        for i in range(cfg.n_enc_layers):
            x, _, _ = apply_layer(params["enc_layers"][f"l{i}"], x, kind="E",
                                  cfg=cfg, run=run, mode="train", probe=probe,
                                  ftc=ftc, name=f"enc{i}", positions=positions)
    else:
        def sb(x, blk_p):
            x, _, _ = apply_layer(blk_p["s0"], x, kind="E", cfg=cfg, run=run,
                                  mode="train", name="enc", positions=positions)
            return x, None
        body = jax.checkpoint(sb, prevent_cse=False) if run.remat == "block" else sb
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ------------------------------------------------------------- embedding ---
def embed_tokens(params, cfg, tokens):
    e = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embeds:
        e = e * jnp.asarray(cfg.d_model ** 0.5, e.dtype)
    return ac(e, "dp", None, None)


def assemble_inputs(params, cfg, batch):
    """Family-specific input embedding.  Returns (x, labels, mask, enc_out)
    where labels/mask are aligned to predict labels[t] from hidden[t]."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x = embed_tokens(params, cfg, tokens)
    enc_out = None
    if cfg.frontend == "vision":
        patches = batch["patch_embeds"].astype(x.dtype)
        if cfg.scale_embeds:
            patches = patches * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
        P = patches.shape[1]
        labels = jnp.concatenate(
            [jnp.full((B, P - 1), -1, jnp.int32), tokens], axis=1)
        mask = labels >= 0
    else:
        labels = tokens[:, 1:]
        mask = jnp.ones_like(labels, bool)
    if cfg.enc_dec:
        enc_out = batch["frames"].astype(x.dtype)
    return x, labels, mask, enc_out


# ------------------------------------------------------------------ loss ---
def chunked_xent(params, cfg, run, h, labels, mask):
    """Cross-entropy over vocab-sharded logits, scanned over token chunks so
    the unsharded (tokens, vocab) tensor never materializes."""
    emb = params.get("unembed", params["embed"])
    # gather the FSDP-sharded unembed ONCE outside the chunk scan: the remat
    # wrapper otherwise re-gathers it per chunk in fwd AND bwd (measured at
    # ~7x params of collective traffic on seamless — EXPERIMENTS.md §Perf)
    emb = ac(emb, "tp", None)
    B = h.shape[0]
    hs = h[:, :labels.shape[1]]
    Sm = labels.shape[1]
    C = min(run.loss_chunk, Sm)
    n = -(-Sm // C)
    pad = n * C - Sm
    if pad:
        hs = jnp.pad(hs, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hs = jnp.moveaxis(hs.reshape(B, n, C, -1), 1, 0)
    labels = jnp.moveaxis(labels.reshape(B, n, C), 1, 0)
    mask = jnp.moveaxis(mask.reshape(B, n, C), 1, 0)

    def body(carry, xs):
        tot, cnt = carry
        hc, lc, mc = xs
        logits = jax.lax.dot_general(
            hc, emb, (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)      # (B, C, V)
        logits = softcap(logits, cfg.logit_softcap)
        logits = ac(logits, "dp", None, "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * mc
        return (tot + nll.sum(), cnt + mc.sum()), None

    # remat: recompute each chunk's logits in backward instead of saving the
    # full (tokens, vocab) tensor
    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs, labels, mask))
    return tot / jnp.maximum(cnt, 1.0)


def last_logits(params, cfg, h, index=None):
    """Logits at the last position, or — for right-padded (bucketed)
    prompts — at a per-row `index` (B,) of the final real token."""
    emb = params.get("unembed", params["embed"])
    hl = h[:, -1] if index is None else jnp.take_along_axis(
        h, jnp.asarray(index, jnp.int32)[:, None, None], axis=1)[:, 0]
    logits = jax.lax.dot_general(hl, emb, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    return softcap(logits, cfg.logit_softcap)
