"""The paper's benchmark CNNs (VGG16-style, ResNet50-style), reduced.

Every convolution runs as an im2col GEMM through ``repro.models.common.
linear`` — exactly how the DLA computes convs on its MAC array — so the
paper's fault-injection / selective-protection stack (``ftc``) and the
importance probe (``probe``) apply to CNNs and LMs through one code path.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, linear, tag


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    arch: str = "vgg"          # vgg | resnet
    channels: tuple = (16, 32)
    n_classes: int = 8
    hw: int = 16
    in_channels: int = 1


def _im2col(x, k: int = 3):
    """x: (B, H, W, C) -> (B, H, W, k*k*C) patches (SAME padding)."""
    B, H, W, C = x.shape
    p = jax.lax.conv_general_dilated_patches(
        jnp.moveaxis(x, -1, 1), (k, k), (1, 1), "SAME")
    # p: (B, C*k*k, H, W) -> (B, H, W, C*k*k)
    return jnp.moveaxis(p, 1, -1)


def conv(params, x, name, probe=None, ftc=None):
    """3x3 conv as an im2col GEMM (the DLA mapping)."""
    patches = _im2col(x)
    y = linear(patches, params["w"], params.get("b"), ftc=ftc, name=name)
    return tag(probe, f"{name}/out", y)


def _conv_init(key, cin, cout, dtype=jnp.float32):
    return {"w": dense_init(key, 9 * cin, cout, dtype),
            "b": jnp.zeros((cout,), dtype)}


def init_cnn(key, cfg: CNNConfig):
    ks = iter(jax.random.split(key, 32))
    p: dict = {}
    cin = cfg.in_channels
    if cfg.arch == "vgg":
        # VGG-style: [conv, conv, pool] per stage
        for si, c in enumerate(cfg.channels):
            p[f"s{si}_c0"] = _conv_init(next(ks), cin, c)
            p[f"s{si}_c1"] = _conv_init(next(ks), c, c)
            cin = c
    elif cfg.arch == "resnet":
        p["stem"] = _conv_init(next(ks), cin, cfg.channels[0])
        cin = cfg.channels[0]
        for si, c in enumerate(cfg.channels):
            p[f"s{si}_c0"] = _conv_init(next(ks), cin, c)
            p[f"s{si}_c1"] = _conv_init(next(ks), c, c)
            if cin != c:
                p[f"s{si}_proj"] = {"w": dense_init(next(ks), cin, c,
                                                    jnp.float32)}
            cin = c
    else:
        raise ValueError(cfg.arch)
    hw = cfg.hw // (2 ** len(cfg.channels))
    p["head"] = {"w": dense_init(next(ks), hw * hw * cin, cfg.n_classes,
                                 jnp.float32),
                 "b": jnp.zeros((cfg.n_classes,), jnp.float32)}
    return p


def _pool(x):
    B, H, W, C = x.shape
    return x.reshape(B, H // 2, 2, W // 2, 2, C).max((2, 4))


def apply_cnn(params, cfg: CNNConfig, images, probe=None, ftc=None):
    x = images
    if cfg.arch == "vgg":
        for si in range(len(cfg.channels)):
            x = jax.nn.relu(conv(params[f"s{si}_c0"], x, f"s{si}_c0",
                                 probe, ftc))
            x = jax.nn.relu(conv(params[f"s{si}_c1"], x, f"s{si}_c1",
                                 probe, ftc))
            x = _pool(x)
    else:
        x = jax.nn.relu(conv(params["stem"], x, "stem", probe, ftc))
        for si in range(len(cfg.channels)):
            h = jax.nn.relu(conv(params[f"s{si}_c0"], x, f"s{si}_c0",
                                 probe, ftc))
            h = conv(params[f"s{si}_c1"], h, f"s{si}_c1", probe, ftc)
            sc = x
            if f"s{si}_proj" in params:
                sc = linear(x, params[f"s{si}_proj"]["w"], ftc=ftc,
                            name=f"s{si}_proj")
            x = jax.nn.relu(h + sc)
            x = _pool(x)
    x = x.reshape(x.shape[0], -1)
    return linear(x, params["head"]["w"], params["head"]["b"], ftc=ftc,
                  name="head")


def xent_loss(logits, labels):
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return (lse - ll).mean()


def accuracy(logits, labels):
    return (jnp.argmax(logits, -1) == labels).mean()


def train_cnn(key, cfg: CNNConfig, steps: int = 300, batch: int = 64,
              lr: float = 3e-3, data_seed: int = 99, noise: float = 1.6,
              fat=None, fat_ber: float = 0.0, fat_ramp: int | None = None):
    """Quick SGD+momentum training on the procedural vision set; returns
    (params, final *clean* train accuracy).

    ``noise=1.6`` puts the reduced benchmark at ~0.98 clean accuracy —
    *off* the 1.0 ceiling.  At lower noise the template task is linearly
    separable with such wide logit margins that soft errors almost never
    flip an argmax, which hides the paper's fault-sensitivity phenomenology
    entirely (see tests/test_cnn_crosslayer.py).  Keep this in sync with
    ``repro.core.evaluate.CnnOracle.noise``.

    Fault-aware training (FAT): ``fat`` names a protection policy (or passes
    one) whose fault model the network trains *through* — the forward runs
    the faulty quantized datapath bit-exactly, gradients flow straight-
    through (``protect_linear_ste``).  The BER ramps linearly 0 -> ``fat_ber``
    over ``fat_ramp`` steps (default ``steps // 2``) and is the only traced
    leaf, so the whole schedule shares one executable.  Data and fault draws
    come from separate folds of the per-step key (FTL001: the streams can
    never collide).  Clean accuracy is evaluated fault-free either way, so
    FAT and baseline networks are compared at matched clean accuracy.
    """
    from repro.data.pipeline import vision_batch
    params = init_cnn(key, cfg)
    mom = jax.tree.map(jnp.zeros_like, params)
    pol = None
    if fat is not None:
        from repro.ft import as_policy
        pol = as_policy(fat)
        ramp = steps // 2 if fat_ramp is None else fat_ramp

    @jax.jit
    def step(params, mom, k, ber):
        imgs, labels = vision_batch(k, batch, cfg.n_classes, cfg.hw,
                                    noise=noise, seed=data_seed)
        ftc = None
        if pol is not None:
            from repro.models.common import FTCtx
            ftc = FTCtx(pol.with_ber(ber), jax.random.fold_in(k, 1),
                        ste=True)

        def loss_fn(p):
            return xent_loss(apply_cnn(p, cfg, imgs, ftc=ftc), labels)
        g = jax.grad(loss_fn)(params)
        mom = jax.tree.map(lambda m, gg: 0.9 * m + gg, mom, g)
        params = jax.tree.map(lambda p, m: p - lr * m, params, mom)
        return params, mom

    for i in range(steps):
        ber = 0.0
        if pol is not None:
            ber = fat_ber * min(i / ramp, 1.0) if ramp > 0 else fat_ber
        params, mom = step(params, mom, jax.random.fold_in(key, i),
                           jnp.float32(ber))
    imgs, labels = vision_batch(jax.random.PRNGKey(7), 512, cfg.n_classes,
                                cfg.hw, noise=noise, seed=data_seed)
    acc = float(accuracy(apply_cnn(params, cfg, imgs), labels))
    return params, acc
