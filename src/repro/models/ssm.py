"""Mamba2 SSD (state-space duality) block.

Chunked SSD algorithm: within-chunk quadratic term + across-chunk state
recurrence via lax.scan, processing one chunk at a time so the largest live
buffer is O(B * H * Lc^2) — bounded regardless of sequence length.  Decode is
an O(1) state update.  Heads shard over the TP axis; batch over DP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, linear, rms_norm, tag, ac


def dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads


def init(key, cfg, dtype):
    s = cfg.ssm
    D = cfg.d_model
    d_inner, H = dims(cfg)
    # single group (G=1) B/C projections, standard for mamba2
    ks = jax.random.split(key, 5)
    conv_ch = d_inner + 2 * s.d_state
    p = {
        # order: [z, x, B, C, dt]
        "in_proj": dense_init(ks[0], D, 2 * d_inner + 2 * s.d_state + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_ch), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.zeros((d_inner,), dtype),
        "out_proj": dense_init(ks[2], d_inner, D, dtype),
    }
    return p


def _causal_conv(x, w, b):
    """Depthwise causal conv.  x: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return out + b[None, None, :]


def _split(cfg, zxbcdt):
    s = cfg.ssm
    d_inner, H = dims(cfg)
    z, x, B, C, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + s.d_state,
                 2 * d_inner + 2 * s.d_state], axis=-1)
    return z, x, B, C, dt


def ssd_chunked(x, dt, A, Bm, Cm, chunk):
    """SSD over full sequences.  x:(B,S,H,P) dt:(B,S,H) A:(H,) Bm/Cm:(B,S,N).
    Returns (y, final_state) with state (B,H,P,N)."""
    Bsz, S, H, Pd = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    xc = x.reshape(Bsz, nc, chunk, H, Pd)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    dA = dtc * A[None, None, None, :]              # positive decay rates
    seg = jnp.cumsum(dA, axis=2)                   # (B,nc,L,H)

    def body(state, inp):
        xi, dti, Bi, Ci, segi = inp                # leading axis nc scanned out
        # in-chunk quadratic term
        Lmat = segi[:, :, None, :] - segi[:, None, :, :]   # (B,Lq,Lk,H)
        iq = jnp.arange(segi.shape[1])
        causal = iq[:, None] >= iq[None, :]
        # mask BEFORE exp so masked entries never overflow (grad-safe)
        Lmat = jnp.where(causal[None, :, :, None], Lmat, jnp.inf)
        dec = jnp.exp(-Lmat)
        scores = jnp.einsum("bqn,bkn->bqk", Ci, Bi)[..., None] * dec
        y_intra = jnp.einsum("bqkh,bkh,bkhp->bqhp", scores, dti, xi)
        # contribution of the carried state
        y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp", Ci, state,
                             jnp.exp(-segi))
        # update state: S' = exp(-seg_last) decayed S + sum_k exp(-(seg_last-seg_k)) dt_k B_k x_k
        seg_last = segi[:, -1:, :]                 # (B,1,H)
        w = jnp.exp(-(seg_last - segi)) * dti      # (B,L,H)
        state_new = (state * jnp.exp(-seg_last)[:, 0, :, None, None]
                     + jnp.einsum("bkh,bkn,bkhp->bhpn", w, Bi, xi))
        return state_new, y_intra + y_inter

    state0 = jnp.zeros((Bsz, H, Pd, N), jnp.float32)
    xs = (jnp.moveaxis(xc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dtc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Cc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(seg, 1, 0).astype(jnp.float32))
    state, yc = jax.lax.scan(body, state0, xs)
    y = jnp.moveaxis(yc, 0, 1).reshape(Bsz, S, H, Pd)
    return y, state


def apply(p, x, *, cfg, run, positions=None, probe=None, ftc=None,
          name="ssd", cache=None, mode="train"):
    """Mamba2 mixer.  Returns (out, new_cache)."""
    s = cfg.ssm
    d_inner, H = dims(cfg)
    B = x.shape[0]
    zxbcdt = linear(x, p["in_proj"], ftc=ftc, name=f"{name}/in_proj")
    z, xi, Bm, Cm, dt = _split(cfg, zxbcdt)
    conv_in = jnp.concatenate([xi, Bm, Cm], axis=-1)

    if mode == "decode":
        # conv state: last K-1 inputs  (B, K-1, C)
        K = s.conv_width
        hist = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B,K,C)
        conv_out = (jnp.einsum("bkc,kc->bc", hist, p["conv_w"])
                    + p["conv_b"])[:, None, :]
        new_conv = hist[:, 1:]
    else:
        conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
        new_conv = conv_in[:, -(s.conv_width - 1):]
    conv_out = jax.nn.silu(conv_out)
    xi, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + s.d_state], axis=-1)

    xh = xi.reshape(B, -1, H, s.head_dim)
    xh = ac(xh, "dp", None, "tp", None)
    A = jnp.exp(p["A_log"])
    dt_s = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    if mode == "decode":
        state = cache["state"]                     # (B,H,P,N)
        dA = jnp.exp(-dt_s[:, 0, :] * A[None, :])  # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt_s[:, 0, :],
                         Bm[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        state = state * dA[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), state)
        y = y[:, None].reshape(B, 1, H, s.head_dim)
        new_cache = {"state": state, "conv": new_conv}
    else:
        S_in = xh.shape[1]
        rem = S_in % s.chunk
        if rem:
            # pad to a chunk multiple; padded steps get dt=0 so they neither
            # decay nor write the state, and their outputs are discarded.
            pad = s.chunk - rem
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
            dt_s = jnp.pad(dt_s, ((0, 0), (0, pad), (0, 0)))
        y, state = ssd_chunked(xh, dt_s, A, Bm, Cm, s.chunk)
        if rem:
            y, xh = y[:, :S_in], xh[:, :S_in]
        y = y.reshape(B, -1, H, s.head_dim)
        new_cache = ({"state": state, "conv": new_conv}
                     if mode == "prefill" else cache)

    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, -1, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    y = tag(probe, f"{name}/out", y)
    return linear(y, p["out_proj"], ftc=ftc, name=f"{name}/out_proj"), new_cache
