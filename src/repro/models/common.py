"""Shared model components: norms, rotary embeddings, inits, activations."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel import ctx as pctx


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------- init -----
def dense_init(key, d_in: int, d_out, dtype, scale: float = 1.0):
    shape = (d_in,) + (tuple(d_out) if isinstance(d_out, (tuple, list))
                       else (d_out,))
    std = scale / (d_in ** 0.5)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    # std 1/sqrt(d): unit-variance logits under a tied unembed; gemma-style
    # input scaling (scale_embeds) restores O(1) activations at the input.
    return (jax.random.truncated_normal(key, -2, 2, (vocab, d), jnp.float32)
            * d ** -0.5).astype(dtype)


# ---------------------------------------------------------------- norms ----
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    # variance statistics in f32, data flow in the compute dtype: keeps the
    # activation (and its cotangent) bf16 so no full-width f32 residual-
    # stream tensors survive into the backward pass
    xf = x.astype(jnp.float32)
    rs = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return x * rs.astype(x.dtype) * (1.0 + scale).astype(x.dtype)


# ---------------------------------------------------------------- rope -----
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding.  x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ activations --
def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[name]


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ------------------------------------------------------------ ft routing ---
class EmuCtx:
    """Structural-cost emulation of FlexHyCA protection (no RNG): used by the
    perf hillclimb to compare the naive TPU port of the DPPU (a second
    gathered GEMM pass over the important channels, 'two_pass') against the
    fused design (protection in the epilogue of the same tile pass — the
    protected_mm kernel; zero extra GEMM cost, 'fused')."""

    def __init__(self, mode: str, s_th: float = 0.05):
        assert mode in ("two_pass", "fused")
        self.mode = mode
        self.s_th = s_th


class FTCtx:
    """Per-forward fault-tolerance context: a ProtectionPolicy (legacy
    FTConfig and registry names are converted) + per-site importance masks +
    deterministic per-site PRNG keys.  None => clean bf16 math.

    ``backend`` selects the protect_linear implementation per forward:
    "reference" (functional model) or "pallas" (fused TPU kernel).  The
    pallas kernel takes the truncation LSB statically, so under jit supply
    ``t`` — one int for all sites or a per-site {name: int} calibration
    table (repro.ft.calibrate_t) — and ``interpret=False`` to run the
    compiled kernel on TPU.

    ``dyn`` optionally carries traced overrides of the policy's numeric
    protection knobs ({"ib_th": ..., "nb_th": ..., "q_scale": ...}) so a
    vmap axis of candidate designs shares one executable — the batched DSE
    oracle path (reference backend only; see ``repro.core.evaluate``).

    ``key`` may be a single PRNG key (one fault stream for the whole
    forward) or a (B, 2) batch of keys — one *independent* stream per batch
    row, so a serving batch keeps per-request fault accounting: row b's
    draws (and its quantization scales) depend only on row b (reference
    backend, weight_faults=False; see ``repro.serve.scheduler``).

    ``ste=True`` routes every site through ``protect_linear_ste`` — forward
    bit-identical to the faulty datapath, backward the clean-matmul
    straight-through gradient — which is what fault-aware training (FAT)
    threads into the train step (see ``repro.train.train_step`` and
    docs/training.md)."""

    def __init__(self, ft, key, masks=None, protected_layers=None,
                 backend: str = "reference", t=None, interpret: bool = True,
                 dyn=None, ste: bool = False):
        from repro.ft import as_policy
        self.ft = as_policy(ft)
        self.key = key
        self.masks = masks or {}
        self.protected_layers = protected_layers  # set of layer names (arch/alg)
        self.backend = backend
        self.t = t
        self.interpret = interpret
        self.dyn = dyn
        self.ste = ste

    def site_key(self, name: str):
        import zlib
        c = zlib.crc32(name.encode())
        if getattr(self.key, "ndim", 1) == 2:      # (B, 2) per-row streams
            return jax.vmap(lambda k: jax.random.fold_in(k, c))(self.key)
        return jax.random.fold_in(self.key, c)

    def site_t(self, name: str):
        return self.t.get(name) if isinstance(self.t, dict) else self.t


def linear(x: jax.Array, w: jax.Array, b=None, *,
           ftc: FTCtx | None = None, name: str = "") -> jax.Array:
    """Every projection in the zoo routes through here — the integration point
    of the paper's technique (ft_linear) with the LM stack."""
    if isinstance(ftc, EmuCtx):
        w2 = w.reshape(w.shape[0], -1)
        y = x @ w2
        if ftc.mode == "two_pass":
            # DPPU as a separate pass: recompute the important channels from
            # a second weight read and vote (naive port of the paper's arch)
            k = max(int(ftc.s_th * w2.shape[1]), 1)
            y_sel = x @ w2[:, :k]
            y = jnp.concatenate(
                [((y[..., :k] + y_sel) * 0.5).astype(y.dtype), y[..., k:]],
                axis=-1)
        y = y.reshape(*x.shape[:-1], *w.shape[1:])
    elif ftc is None or ftc.ft is None:
        y = x @ w.reshape(w.shape[0], -1)
        y = y.reshape(*x.shape[:-1], *w.shape[1:])
    else:
        from repro.ft import protect_linear, protect_linear_ste
        pl = protect_linear_ste if ftc.ste else protect_linear
        w2 = w.reshape(w.shape[0], -1).astype(jnp.float32)
        imp = ftc.masks.get(name)
        prot = (ftc.protected_layers is None
                or name.split("/")[0] in ftc.protected_layers)
        sk = ftc.site_key(name)
        if getattr(sk, "ndim", 1) == 2:
            # batched per-row streams: the FTCtx carries one key per batch
            # row; x flattens to (B*S, K) row-major, so each row-key repeats
            # over that row's S positions.
            reps = max(x.size // x.shape[-1], 1) // sk.shape[0]
            if reps != 1:
                sk = jnp.repeat(sk, reps, axis=0)
        y = pl(sk,
               x.astype(jnp.float32).reshape(-1, w.shape[0]),
               w2, ftc.ft,
               important=None if imp is None else jnp.asarray(imp),
               layer_protected=prot, backend=ftc.backend,
               t=ftc.site_t(name), interpret=ftc.interpret,
               dyn=ftc.dyn)
        y = y.reshape(*x.shape[:-1], *w.shape[1:]).astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def tag(probe, name: str, x: jax.Array) -> jax.Array:
    """Neuron-importance tap site (Algorithm 1)."""
    return x if probe is None else probe.tag(name, x)


ac = pctx.ac  # re-export: activation sharding constraint
