"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Gated linear recurrence h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
with a_t = exp(-c * softplus(Lambda) * r_t).  As in the RecurrentGemma
reference, the recurrence/input gates are *block-diagonal* linears (one block
per head) — so with heads sharded over 'model' the whole recurrence is
communication-free.  Train/prefill uses a log-depth associative scan over the
sequence; decode is an O(1) state update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, linear, tag, ac

C_FACTOR = 8.0


def _gate_init(key, heads, bw, dtype):
    ks = jax.random.split(key, heads)
    return jnp.stack([dense_init(k, bw, bw, dtype) for k in ks])


def init(key, cfg, dtype):
    D, W = cfg.d_model, cfg.rglru_width
    nh = max(cfg.n_heads, 1)
    assert W % nh == 0
    bw = W // nh
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], D, W, dtype),
        "w_gate": dense_init(ks[1], D, W, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.rglru_conv, W), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((W,), dtype),
        # block-diagonal gate weights: (heads, bw, bw)
        "w_a": _gate_init(ks[3], nh, bw, dtype),
        "b_a": jnp.zeros((W,), jnp.float32),
        "w_i": _gate_init(ks[4], nh, bw, dtype),
        "b_i": jnp.zeros((W,), jnp.float32),
        # init recurrence decay in a stable range (a ~ 0.9..0.999)
        "lam": jnp.linspace(0.3, 1.5, W).astype(jnp.float32),
        "w_out": dense_init(ks[5], W, D, dtype),
    }


def _conv(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
               for i in range(K)) + b[None, None, :]


def _block_diag(x, w):
    """x: (B,S,W) -> (B,S,W) through per-head (bw x bw) blocks."""
    nh, bw, _ = w.shape
    B, S, W = x.shape
    xh = x.reshape(B, S, nh, bw)
    y = jnp.einsum("bshw,hwv->bshv", xh, w)
    return y.reshape(B, S, W)


def _recurrence(a, bx):
    """h_t = a_t h_{t-1} + bx_t via associative scan over axis 1."""
    def combine(lhs, rhs):
        al, bl = lhs
        ar, br = rhs
        return al * ar, bl * ar + br
    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def apply(p, x, *, cfg, run, positions=None, probe=None, ftc=None,
          name="rglru", cache=None, mode="train"):
    """Returns (out, new_cache).  cache: {'h': (B,W), 'conv': (B,K-1,W)}."""
    B = x.shape[0]
    gate = jax.nn.gelu(linear(x, p["w_gate"], ftc=ftc, name=f"{name}/w_gate"))
    xb = linear(x, p["w_x"], ftc=ftc, name=f"{name}/w_x")

    if mode == "decode":
        K = cfg.rglru_conv
        hist = jnp.concatenate([cache["conv"], xb], axis=1)
        xc = (jnp.einsum("bkc,kc->bc", hist, p["conv_w"])
              + p["conv_b"])[:, None, :]
        new_conv = hist[:, 1:]
    else:
        xc = _conv(xb, p["conv_w"], p["conv_b"])
        new_conv = xb[:, -(cfg.rglru_conv - 1):]
    xc = ac(xc, "dp", None, "tp")

    r = jax.nn.sigmoid(_block_diag(xc, p["w_a"]).astype(jnp.float32)
                       + p["b_a"])
    i = jax.nn.sigmoid(_block_diag(xc, p["w_i"]).astype(jnp.float32)
                       + p["b_i"])
    r = ac(r, "dp", None, "tp")
    i = ac(i, "dp", None, "tp")
    xf = xc.astype(jnp.float32)
    log_a = -C_FACTOR * jax.nn.softplus(p["lam"])[None, None, :] * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9))
    bx = beta * (i * xf)

    if mode == "decode":
        h = a[:, 0] * cache["h"] + bx[:, 0]
        new_cache = {"h": h, "conv": new_conv}
        hseq = h[:, None, :]
    else:
        hseq = _recurrence(a, bx)
        new_cache = ({"h": hseq[:, -1], "conv": new_conv}
                     if mode == "prefill" else cache)
    hseq = ac(hseq, "dp", None, "tp")

    y = (hseq * gate.astype(jnp.float32)).astype(x.dtype)
    y = tag(probe, f"{name}/out", y)
    return linear(y, p["w_out"], ftc=ftc, name=f"{name}/w_out"), new_cache
