"""Mixture-of-Experts FFN with partial-sum expert parallelism.

Sharding scheme (DESIGN.md §5): experts live on the TP ('model') axis; token
activations are batch-sharded over the DP axes and replicated over TP (as in
ordinary tensor parallelism).  Each (dp, tp) shard routes its local tokens,
keeps only the assignments that hit its *local* experts, computes them on
capacity-bounded buffers, and scatter-adds weighted outputs; the cross-expert
combine is a single psum over 'model' — the same all-reduce a dense TP FFN
needs, so EP adds **no extra collective**.  Dispatch is sort-based (argsort +
gather/scatter), never a (T, E, C) one-hot einsum, keeping the dispatch
working set O(T*k) instead of O(T*E*C).

Expert weights are additionally FSDP-sharded over the DP axes; the shard_map
boundary performs the per-layer FSDP all-gather.

Fault layer: the router projection is the one MoE site under the paper's
protection stack — it runs through ``common.linear`` (fault-tolerant DLA
path) *outside* the shard_map region, where routing is row-local, so
per-request fault accounting survives and the draws are partition-exact
under GSPMD (counter-based RNG).  The expert einsums stay clean: their
capacity buffers are shard-local (contents depend on the partitioning), so
buffer-addressed fault draws there could never be partition-exact — any
per-shard draws inside shard_map must use ``faults.fold_axis_index``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import activation, dense_init, linear
from repro.parallel import ctx as pctx
from repro.parallel.compat import shard_map


def init(key, cfg, dtype):
    D, m = cfg.d_model, cfg.moe
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], D, m.n_experts, jnp.float32),
        "wi": _expert_init(ks[1], m.n_experts, D, m.d_ff, dtype),
        "wo": _expert_init(ks[2], m.n_experts, m.d_ff, D, dtype),
    }
    if cfg.glu:
        p["wg"] = _expert_init(ks[3], m.n_experts, D, m.d_ff, dtype)
    return p


def _expert_init(key, E, d_in, d_out, dtype):
    ks = jax.random.split(key, E)
    return jnp.stack([dense_init(k, d_in, d_out, dtype) for k in ks])


def _local_moe(x, logits, wi, wg, wo, *, e0, n_experts, top_k, capacity,
               act_name, tp_axis=None):
    """Per-shard MoE over local experts [e0, e0+E_local).  x: (B, S, D);
    logits: (B, S, E) pre-computed router logits (see ``apply``)."""
    B, S, D = x.shape
    E_local = wi.shape[0]
    T = B * S
    x2 = x.reshape(T, D)
    act = activation(act_name)

    logits = logits.astype(jnp.float32).reshape(T, -1)    # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, top_k)              # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    flat_e = topi.reshape(-1)                             # (T*k,)
    flat_w = topw.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), top_k)

    rel = flat_e - e0
    mine = (rel >= 0) & (rel < E_local)
    sort_key = jnp.where(mine, rel, E_local)
    order = jnp.argsort(sort_key, stable=True)
    srel = sort_key[order]
    pos = jnp.arange(T * top_k) - jnp.searchsorted(srel, srel, side="left")
    keep = (srel < E_local) & (pos < capacity)
    slot = jnp.where(keep, srel * capacity + pos, E_local * capacity)

    tok = flat_tok[order]
    # slot-indexed dispatch: build a (slots -> token) index table and gather
    # straight into the (E_local*C, D) buffer — never materializes the
    # (T*k, D) flat-assignment tensor (which is 8x the token activations)
    n_slots = E_local * capacity
    slot_tok = jnp.full((n_slots + 1,), T, jnp.int32).at[slot].set(
        tok.astype(jnp.int32), mode="drop")
    slot_valid = slot_tok[:n_slots] < T
    x2p = jnp.concatenate([x2, jnp.zeros((1, D), x2.dtype)], 0)
    buf = (x2p[slot_tok[:n_slots]]
           * slot_valid[:, None].astype(x2.dtype)).reshape(
               E_local, capacity, D)

    h = jnp.einsum("ecd,edf->ecf", buf, wi)
    if wg is not None:
        h = act(h) * jnp.einsum("ecd,edf->ecf", buf, wg)
    else:
        h = act(h)
    y = jnp.einsum("ecf,efd->ecd", h, wo).reshape(n_slots, D)
    y = jnp.concatenate([y, jnp.zeros((1, D), y.dtype)], 0)

    # return path: per-token (token, k) -> slot table, then k small gathers
    # accumulated sequentially (k x (T, D) instead of one (T*k, D))
    slot_of = jnp.full((T * top_k,), n_slots, jnp.int32).at[order].set(
        jnp.where(keep, slot, n_slots).astype(jnp.int32)).reshape(T, top_k)
    out = jnp.zeros((T, D), y.dtype)
    for kk in range(top_k):
        out = out + y[slot_of[:, kk]] * topw[:, kk, None].astype(y.dtype)
    if tp_axis is not None:
        out = jax.lax.psum(out, tp_axis)

    # switch-style load-balance aux loss (computed identically on every tp
    # shard from the replicated activations; returned per dp shard)
    one_hot_top1 = jax.nn.one_hot(topi[:, 0], n_experts, dtype=jnp.float32)
    frac = one_hot_top1.mean(0)
    lb = n_experts * jnp.sum(frac * probs.mean(0))
    return out.reshape(B, S, D), lb.reshape(1)


def apply(p, x, cfg, probe=None, ftc=None, name="moe"):
    """Returns (y, aux_loss_scalar)."""
    m = cfg.moe
    ctx = pctx.get_ctx()
    wg = p.get("wg")
    use_shard_map = (
        ctx is not None and m.n_experts % ctx.tp_size == 0
        and (x.shape[0] * ctx.mesh.size) >= 1 and x.shape[0] % ctx.dp_size == 0)

    # router under the fault layer, outside any shard_map: routing is
    # row-local, so per-request (B, 2) key streams apply unchanged, and the
    # draws are identical at TP=1 and TP=N (counter-based RNG).  x cast to
    # f32 keeps the clean path's router numerics (router weights are f32).
    logits = linear(x.astype(jnp.float32), p["router"], ftc=ftc,
                    name=f"{name}/router")

    if not use_shard_map:
        T = x.shape[0] * x.shape[1]
        cap = max(int(m.capacity_factor * T * m.top_k / m.n_experts), 1)
        one = dict(e0=0, n_experts=m.n_experts, top_k=m.top_k, capacity=cap,
                   act_name=cfg.act)
        if ctx is None:
            y, lb = _local_moe(x, logits, p["wi"], wg, p["wo"], **one)
        else:
            # B doesn't divide dp (e.g. a single-request prefill on a dp>1
            # mesh).  GSPMD's uneven-batch padding is NOT safe through the
            # sentinel-indexed sort/scatter dispatch — on a 2-D mesh the
            # auto-partitioned graph routes differently from the meshless
            # one — so run the whole block per-device on replicated
            # operands: bit-identical to the single-shard path by
            # construction (tests/test_serve_sharded.py, MoE scheduler arm).
            wg_arg = jnp.zeros((), x.dtype) if wg is None else wg
            y, lb = shard_map(
                lambda xs, lg, wi, wg_, wo: _local_moe(
                    xs, lg, wi, None if wg is None else wg_, wo, **one),
                mesh=ctx.mesh, in_specs=(P(), P(), P(), P(), P()),
                out_specs=(P(), P()), check=False)(
                    x, logits, p["wi"], wg_arg, p["wo"])
        return y, cfg.moe.aux_coef * lb.mean()

    dp_spec = ctx.resolve("dp")[0]
    tp = ctx.tp
    T_local = (x.shape[0] // ctx.dp_size) * x.shape[1]
    cap = max(int(m.capacity_factor * T_local * m.top_k / m.n_experts), 1)

    def shard_fn(xs, lg, wi, wg_, wo):
        e0 = jax.lax.axis_index(tp) * (m.n_experts // ctx.tp_size)
        return _local_moe(xs, lg, wi, wg_, wo, e0=e0, n_experts=m.n_experts,
                          top_k=m.top_k, capacity=cap, act_name=cfg.act,
                          tp_axis=tp)

    in_specs = (P(dp_spec, None, None), P(dp_spec, None, None),
                P(tp, None, None), P(tp, None, None) if wg is not None else P(),
                P(tp, None, None))
    out_specs = (P(dp_spec, None, None), P(dp_spec))
    if wg is None:
        wg_arg = jnp.zeros((), x.dtype)
    else:
        wg_arg = wg
    y, lb = shard_map(
        lambda xs, lg, wi, wg_, wo: shard_fn(
            xs, lg, wi, None if wg is None else wg_, wo),
        mesh=ctx.mesh, in_specs=in_specs, out_specs=out_specs,
        check=False)(x, logits, p["wi"], wg_arg, p["wo"])
    return y, cfg.moe.aux_coef * lb.mean()
