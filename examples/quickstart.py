"""Quickstart: the paper's cross-layer fault-tolerance stack in 60 seconds,
through the unified ``repro.ft`` protection-policy API.

  PYTHONPATH=src python examples/quickstart.py

1. computes a linear layer through the bit-exact DLA datapath,
2. injects soft errors at BER 1e-2 and watches accuracy collapse
   (``ft.get_policy("base")`` — the unprotected design),
3. turns on the paper's cross-layer policy (``ft.get_policy("cl")``:
   important neurons via Algorithm 1 + high-bit TMR + Q_scale constraint)
   and watches it recover,
4. sweeps the BER axis with one vmapped executable — policies are pytrees
   whose only dynamic leaf is ``ber``, so no re-jit per operating point,
5. prices the protection with the circuit-level area model.

Backends: the same call runs the fused Pallas TPU kernel with
``backend="pallas"`` (see ``repro.kernels.protected_mm``).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import ft
from repro.core import area
from repro.core.flexhyca import clean_linear

kx, kw, kfault = jax.random.split(jax.random.PRNGKey(0), 3)
x = jax.random.normal(kx, (128, 256))
w = jax.random.normal(kw, (256, 64))
ref = clean_linear(x, w)


def rel_rms(y):
    return float(jnp.sqrt(jnp.mean((y - ref) ** 2))
                 / jnp.sqrt(jnp.mean(ref ** 2)))


BER = 1e-2
print(f"substrate BER = {BER} (compute-array soft errors; weight SRAM has ECC)")

# --- unprotected DLA -------------------------------------------------------
base = ft.get_policy("base", ber=BER, weight_faults=False)
y_base = ft.protect_linear(kfault, x, w, base)
print(f"unprotected      rel-RMS error: {rel_rms(y_base):.4f}")

# --- the paper's cross-layer protection ------------------------------------
# neuron dimension: mark the 10% of output channels with the largest
# downstream weight as important (a stand-in for Algorithm 1's gradients)
importance = jnp.abs(w).sum(0)
thresh = jnp.percentile(importance, 90)
important = importance >= thresh

cl = ft.get_policy("cl", ber=BER, s_th=0.1, ib_th=4, nb_th=2, q_scale=7,
                   weight_faults=False)
# ftlint: disable=FTL001 -- same fault stream as the unprotected design
y_cl = ft.protect_linear(kfault, x, w, cl, important=important)
print(f"TMR-CL protected rel-RMS error: {rel_rms(y_cl):.4f}")

# --- sweep the BER axis with one compiled executable -----------------------
bers = jnp.array([1e-4, 1e-3, 1e-2, 5e-2], jnp.float32)
sweep = jax.vmap(lambda p: ft.protect_linear(kfault, x, w, p,
                                             important=important))
ys = sweep(cl.with_ber(bers))
errs = ", ".join(f"{float(b):g}: {rel_rms(y):.4f}" for b, y in zip(bers, ys))
print(f"vmapped BER sweep (TMR-CL) — {errs}")

# --- what does it cost in silicon? ------------------------------------------
r = area.array_area(32, nb_th=cl.circuit.nb_th, q_scale=cl.algorithm.q_scale,
                    pe_policy=cl.circuit.pe_policy,
                    dot_size=cl.arch.dot_size, ib_th=cl.circuit.ib_th)
full_tmr = area.full_tmr_pe_cost() / area.pe_cost()
print(f"area overhead: {r['overhead'] * 100:.1f}% of the 2-D array "
      f"(classic TMR: {100 * (full_tmr - 1):.0f}%)")
