"""Quickstart: the paper's cross-layer fault-tolerance stack in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py

1. computes a linear layer through the bit-exact DLA datapath,
2. injects soft errors at BER 1e-2 and watches accuracy collapse,
3. turns on the paper's selective protection (important neurons via
   Algorithm 1 + high-bit TMR + Q_scale constraint) and watches it recover,
4. prices the protection with the circuit-level area model.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import area
from repro.core.flexhyca import FTConfig, clean_linear, ft_linear

key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (128, 256))
w = jax.random.normal(jax.random.fold_in(key, 1), (256, 64))
ref = clean_linear(x, w)


def rel_rms(y):
    return float(jnp.sqrt(jnp.mean((y - ref) ** 2))
                 / jnp.sqrt(jnp.mean(ref ** 2)))


BER = 1e-2
print(f"substrate BER = {BER} (compute-array soft errors; weight SRAM has ECC)")

# --- unprotected DLA -------------------------------------------------------
y_base = ft_linear(key, x, w, FTConfig(ber=BER, strategy="base",
                                       weight_faults=False))
print(f"unprotected      rel-RMS error: {rel_rms(y_base):.4f}")

# --- the paper's cross-layer protection ------------------------------------
# neuron dimension: mark the 10% of output channels with the largest
# downstream weight as important (a stand-in for Algorithm 1's gradients)
importance = jnp.abs(w).sum(0)
thresh = jnp.percentile(importance, 90)
important = importance >= thresh

ft = FTConfig(ber=BER, strategy="cl", s_th=0.1, ib_th=4, nb_th=2, q_scale=7,
              pe_policy="configurable", dot_size=52, weight_faults=False)
y_cl = ft_linear(key, x, w, ft, important=important)
print(f"TMR-CL protected rel-RMS error: {rel_rms(y_cl):.4f}")

# --- what does it cost in silicon? ------------------------------------------
r = area.array_area(32, nb_th=ft.nb_th, q_scale=ft.q_scale,
                    pe_policy=ft.pe_policy, dot_size=ft.dot_size,
                    ib_th=ft.ib_th)
full_tmr = area.full_tmr_pe_cost() / area.pe_cost()
print(f"area overhead: {r['overhead'] * 100:.1f}% of the 2-D array "
      f"(classic TMR: {100 * (full_tmr - 1):.0f}%)")
