"""Serve a small LM while the substrate injects soft errors — and watch a
``repro.ft`` protection policy keep generations stable.

  PYTHONPATH=src python examples/fault_tolerant_serving.py

The serving engine takes a protection policy directly: every projection of
prefill and decode then computes through the faulty quantized DLA path with
that policy's cross-layer protection applied.  The decode loop is a single
fused ``lax.scan`` executable (2 host dispatches per generation — see
docs/serving.md); the final section serves a small request queue through
the continuous-batching scheduler with per-request fault streams.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro import ft
from repro.configs import get_config
from repro.models import build
from repro.serve.engine import Engine, ServeConfig


def main():
    cfg = get_config("h2o-danube-1.8b", reduced=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    serve_cfg = ServeConfig(max_new_tokens=8)
    engine = Engine(model, params, cfg=serve_cfg)

    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12),
                                            0, cfg.vocab)}
    clean = engine.generate(prompts)
    print("clean generations:\n", np.asarray(clean))

    # Same engine, same weights, but the DLA substrate now flips bits at BER:
    # compare the unprotected design against circuit-level TMR of the top-3
    # output bits (both straight from the policy registry).
    ber = 2e-3
    for name in ("base", "crt3"):
        policy = ft.get_policy(name, ber=ber, weight_faults=False)
        faulty = Engine(model, params, cfg=serve_cfg, policy=policy)
        gen = faulty.generate(prompts)
        agree = float(np.mean(np.asarray(gen) == np.asarray(clean)))
        print(f"BER {ber:g} under {name!r}: "
              f"token agreement with clean = {agree:.2f}")

    print("\n(the cross-layer 'cl' policy additionally recomputes "
          "important channels on the DPPU — feed Algorithm-1 masks through "
          "FTCtx(masks=...); see examples/crosslayer_dse.py)")

    # Continuous batching: a queue of requests through a fixed slot pool,
    # each with its own fault-key stream (alone or crowded, a request's
    # generation is bit-identical — per-request reliability accounting).
    from repro.serve.scheduler import Request, Scheduler, SchedulerConfig
    sched = Scheduler(model, params,
                      SchedulerConfig(max_batch=2, buckets=(8, 16),
                                      max_new_tokens=8, decode_chunk=4),
                      policy=ft.get_policy("crt3", ber=ber,
                                           weight_faults=False))
    reqs = [Request(rid=i, tokens=[int(t) for t in np.asarray(
                prompts["tokens"][i % 2][:8 + 4 * (i % 2)])],
                    max_new_tokens=8) for i in range(4)]
    done = sched.run(reqs)
    for i in sorted(done):
        r = done[i]
        print(f"request {i}: {r.generated} ({r.finish_reason}; "
              f"{len(r.generated)} tokens)")
    print(f"scheduler roundtrips: {sched.stats.roundtrips} for "
          f"{sched.stats.tokens} tokens across {len(reqs)} requests")


if __name__ == "__main__":
    main()
