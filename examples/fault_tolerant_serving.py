"""Serve a small LM while the substrate injects soft errors — and watch a
``repro.ft`` protection policy keep generations stable.

  PYTHONPATH=src python examples/fault_tolerant_serving.py

The serving engine takes a protection policy directly: every projection of
prefill and decode then computes through the faulty quantized DLA path with
that policy's cross-layer protection applied.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro import ft
from repro.configs import get_config
from repro.models import build
from repro.serve.engine import Engine, ServeConfig


def main():
    cfg = get_config("h2o-danube-1.8b", reduced=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    serve_cfg = ServeConfig(max_new_tokens=8)
    engine = Engine(model, params, cfg=serve_cfg)

    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12),
                                            0, cfg.vocab)}
    clean = engine.generate(prompts)
    print("clean generations:\n", np.asarray(clean))

    # Same engine, same weights, but the DLA substrate now flips bits at BER:
    # compare the unprotected design against circuit-level TMR of the top-3
    # output bits (both straight from the policy registry).
    ber = 2e-3
    for name in ("base", "crt3"):
        policy = ft.get_policy(name, ber=ber, weight_faults=False)
        faulty = Engine(model, params, cfg=serve_cfg, policy=policy)
        gen = faulty.generate(prompts)
        agree = float(np.mean(np.asarray(gen) == np.asarray(clean)))
        print(f"BER {ber:g} under {name!r}: "
              f"token agreement with clean = {agree:.2f}")

    print("\n(the cross-layer 'cl' policy additionally recomputes "
          "important channels on the DPPU — feed Algorithm-1 masks through "
          "FTCtx(masks=...); see examples/crosslayer_dse.py)")


if __name__ == "__main__":
    main()
