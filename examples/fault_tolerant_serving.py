"""Serve a small LM with batched requests while the substrate injects soft
errors — and watch selective protection keep generations stable.

  PYTHONPATH=src python examples/fault_tolerant_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build
from repro.models.common import FTCtx
from repro.core.flexhyca import FTConfig
from repro.serve.engine import Engine, ServeConfig


def main():
    cfg = get_config("h2o-danube-1.8b", reduced=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, cfg=ServeConfig(max_new_tokens=16))

    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 12),
                                            0, cfg.vocab)}
    clean = engine.generate(prompts)
    print("clean generations:\n", np.asarray(clean))

    # Emulate decode on a faulty substrate by perturbing the weights with the
    # DLA fault model (weight SRAM upsets), then serve base vs protected.
    from repro.core import faults, quantization as Q

    def corrupt(params, ber, key):
        flat, td = jax.tree_util.tree_flatten(params)
        out = []
        for i, leaf in enumerate(flat):
            if leaf.ndim >= 2:
                q, s = Q.quantize(leaf.astype(jnp.float32))
                qf = faults.inject_weight_faults(
                    jax.random.fold_in(key, i), q, ber)
                out.append((qf.astype(jnp.float32) * s).astype(leaf.dtype))
            else:
                out.append(leaf)
        return jax.tree_util.tree_unflatten(td, out)

    for ber in (1e-5, 1e-4):
        bad = Engine(model, corrupt(params, ber, jax.random.PRNGKey(9)),
                     cfg=ServeConfig(max_new_tokens=16))
        gen = bad.generate(prompts)
        agree = float(jnp.mean(gen == clean))
        print(f"BER {ber:g}: token agreement with clean = {agree:.2f}")

    print("\n(with the paper's protection the high bits of every weight are "
          "TMR'd in the PE array; see tests/test_flexhyca.py and the "
          "protected_mm kernel for the per-matmul path)")


if __name__ == "__main__":
    main()
