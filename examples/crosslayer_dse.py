"""Run the paper's full cross-layer design-space exploration (Fig. 1 / Alg. 3)
on the reduced VGG benchmark and print the Table-II-style optimum.

  PYTHONPATH=src python examples/crosslayer_dse.py [--ber 1e-3] [--iters 16] \
      [--batch 8]

--batch q proposes q candidates per BO round (constant-liar q-EI) and
evaluates them through the vmapped batch oracle — one compiled executable
per candidate *structure* instead of one per candidate (see docs/dse.md).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import bayesopt as B
from repro.core.evaluate import trained_cnn
from repro.core.pipeline import optimize


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ber", type=float, default=1e-3)
    ap.add_argument("--iters", type=int, default=16)
    ap.add_argument("--batch", type=int, default=1,
                    help="DSE candidates evaluated per BO round (q-EI)")
    args = ap.parse_args()

    print("training the reduced VGG benchmark ...")
    oracle = trained_cnn("vgg", steps=250)
    clean = oracle.accuracy(None)
    print(f"clean accuracy: {clean:.3f}")

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.workloads import vgg16_gemms
    cons = B.Constraints(acc_min=0.97 * clean, perf_max=0.10, bw_max=0.10)
    print(f"constraints: acc >= {cons.acc_min:.3f}, perf/bw loss <= 10%")

    res = optimize(lambda pol: oracle.accuracy(pol), vgg16_gemms(), cons,
                   args.ber, iter_max_step=args.iters, seed=0,
                   batch_size=args.batch,
                   acc_oracle_batch=oracle.accuracy_batch)
    if res.policy is None:
        print("no feasible design found — raise --iters")
        return
    pol = res.policy
    print("\noptimized cross-layer design (cf. paper Table II):")
    for layer in (pol.algorithm, pol.arch, pol.circuit):
        for f, v in vars(layer).items():
            print(f"  {f:16s} = {v}")
    print(f"  area overhead = {res.area_overhead*100:.1f}% "
          f"(evaluations: {res.dse.evaluations}, pruned: {res.dse.pruned})")
    print(f"  accuracy under fault: {oracle.accuracy(pol):.3f}")


if __name__ == "__main__":
    main()
