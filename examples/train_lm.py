"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
the synthetic pipeline, with checkpoint/restart and straggler accounting.

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--d-model 512]

The config is an h2o-danube-family model scaled to ~100M params.  On CPU this
takes a few minutes; on a real mesh pass --mesh to shard (see
repro/launch/train.py for the production launcher).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.configs import get_config
from repro.configs.base import RunConfig, ShapeConfig
from repro.models import build
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_config("h2o-danube-1.8b")
    cfg = dataclasses.replace(
        base, name="danube-100m", n_layers=args.layers,
        d_model=args.d_model, n_heads=8, n_kv_heads=4,
        d_head=args.d_model // 8, d_ff=args.d_model * 3, vocab=8192,
        window=args.seq // 2, unroll=False)
    model = build(cfg, RunConfig(param_dtype="float32",
                                 compute_dtype="float32"))

    import jax
    import numpy as np
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(model.param_specs()))
    print(f"model: {cfg.name}  params={n/1e6:.1f}M")

    shape = ShapeConfig("train", "train", args.seq, args.batch)
    tc = TrainerConfig(total_steps=args.steps, ckpt_every=100,
                       ckpt_dir=args.ckpt_dir, log_every=10)
    trainer = Trainer(model, shape,
                      AdamWConfig(lr=6e-3, warmup_steps=20,
                                  decay_steps=args.steps), tc)
    state, step = trainer.run()
    losses = [r["loss"] for r in trainer.metrics_log]
    print(f"done at step {step}: loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"stragglers observed: {trainer.straggler_events}")
    trainer.save_metrics(os.path.join(args.ckpt_dir, "metrics.jsonl"))


if __name__ == "__main__":
    main()
