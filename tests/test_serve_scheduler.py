"""Continuous-batching scheduler: eviction, bucket reuse, per-request
fault-stream independence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ft
from repro.configs import get_config
from repro.models import build
from repro.serve.engine import Engine, ServeConfig
from repro.serve.scheduler import Request, Scheduler, SchedulerConfig


@pytest.fixture(scope="module")
def danube():
    cfg = get_config("h2o-danube-1.8b", reduced=True)
    m = build(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _prompt(n, vocab, seed):
    return [int(t) for t in jax.random.randint(jax.random.PRNGKey(seed),
                                               (n,), 0, vocab)]


def test_scheduler_matches_engine_greedy(danube):
    """A lone request through the bucketed scheduler (padded prefill,
    per-row positions, batch slots mostly idle) must emit exactly what the
    engine emits for the same prompt — bucketing is a pure optimization."""
    cfg, m, params = danube
    prompt = _prompt(6, cfg.vocab, seed=1)
    sched = Scheduler(m, params, SchedulerConfig(
        max_batch=3, buckets=(8,), max_new_tokens=10, decode_chunk=4))
    out = sched.run([Request(rid=0, tokens=prompt, max_new_tokens=10)])
    eng = Engine(m, params, cfg=ServeConfig(max_new_tokens=10))
    ref = np.asarray(eng.generate(
        {"tokens": jnp.asarray([prompt], jnp.int32)}))[0]
    assert out[0].generated == [int(t) for t in ref]
    assert out[0].finish_reason == "length"


def test_eos_and_length_eviction_reuse_slots(danube):
    """More requests than slots: every request completes; EOS truncates at
    the EOS token; the freed slot serves the queue."""
    cfg, m, params = danube
    mk = lambda: [Request(rid=i, tokens=_prompt(4 + i % 3, cfg.vocab, i),
                          max_new_tokens=6 + (i % 2)) for i in range(5)]
    sched = Scheduler(m, params, SchedulerConfig(
        max_batch=2, buckets=(8,), max_new_tokens=8, decode_chunk=3))
    probe = sched.run(mk())
    assert set(probe) == set(range(5))
    assert all(r.finish_reason == "length" for r in probe.values())
    assert all(len(r.generated) == 6 + (i % 2) for i, r in probe.items())
    # pick a token some request emits mid-stream and declare it EOS
    rid, toks = 0, probe[0].generated
    eos = toks[2]
    first = toks.index(eos)
    sched2 = Scheduler(m, params, SchedulerConfig(
        max_batch=2, buckets=(8,), max_new_tokens=8, decode_chunk=3,
        eos_id=eos))
    done = sched2.run(mk())
    assert set(done) == set(range(5))
    assert done[rid].finish_reason == "eos"
    assert done[rid].generated == toks[:first + 1]       # truncated at EOS
    assert done[rid].generated[-1] == eos


def test_bucket_reuse_bounds_recompiles(danube):
    """Prompt lengths 3/5/7/11 under buckets (8, 16): exactly one prefill
    executable per *bucket* (not per length), one chunk executable."""
    cfg, m, params = danube
    sched = Scheduler(m, params, SchedulerConfig(
        max_batch=2, buckets=(8, 16), max_new_tokens=4, decode_chunk=2))
    reqs = [Request(rid=i, tokens=_prompt(n, cfg.vocab, i), max_new_tokens=4)
            for i, n in enumerate((3, 5, 7, 11))]
    out = sched.run(reqs)
    assert all(len(r.generated) == 4 for r in out.values())
    assert sched._prefill_one._cache_size() == 2         # one per bucket
    assert sched._chunk._cache_size() == 1
    assert sched._insert._cache_size() == 1
    # longer prompts than any bucket are rejected, not silently truncated
    with pytest.raises(ValueError):
        sched.run([Request(rid=9, tokens=_prompt(20, cfg.vocab, 9))])


def test_per_request_fault_stream_independence(danube):
    """Under a protection policy with faults, a request's generation is a
    pure function of (request id, its own tokens): serving it alone or
    beside other traffic yields bit-identical tokens, so reliability
    accounting stays per-request."""
    cfg, m, params = danube
    # ber high enough that some flip lands an argmax change within 8 tokens
    # on any key stream (the partitionable-threefry stream at 3e-3 happens
    # to leave this short generation clean)
    policy = ft.get_policy("crt1", ber=1e-2, weight_faults=False)
    scfg = SchedulerConfig(max_batch=3, buckets=(8,), max_new_tokens=8,
                           decode_chunk=4)
    a_alone = Scheduler(m, params, scfg, policy=policy).run(
        [Request(rid=7, tokens=_prompt(5, cfg.vocab, 7), max_new_tokens=8)])
    crowd = [Request(rid=7, tokens=_prompt(5, cfg.vocab, 7),
                     max_new_tokens=8),
             Request(rid=8, tokens=_prompt(3, cfg.vocab, 8),
                     max_new_tokens=8),
             Request(rid=9, tokens=_prompt(7, cfg.vocab, 9),
                     max_new_tokens=8)]
    a_crowded = Scheduler(m, params, scfg, policy=policy).run(crowd)
    assert a_alone[7].generated == a_crowded[7].generated
    # faults are real: the protected stream differs from the clean one
    clean = Scheduler(m, params, scfg).run(
        [Request(rid=7, tokens=_prompt(5, cfg.vocab, 7), max_new_tokens=8)])
    assert clean[7].generated != a_alone[7].generated


def test_scheduler_guards(danube):
    cfg, m, params = danube
    # sliding-window models: buckets must fit inside the window
    with pytest.raises(ValueError, match="window"):
        Scheduler(m, params, SchedulerConfig(buckets=(8, 64)))
    # recurrent state would integrate pad tokens under *bucketed* prefill
    ssm_cfg = get_config("mamba2-2.7b", reduced=True)
    ssm = build(ssm_cfg)
    with pytest.raises(ValueError, match="attention"):
        Scheduler(ssm, ssm.init(jax.random.PRNGKey(0)))
    # exact-length prefill needs an explicit capacity bound
    with pytest.raises(ValueError, match="max_prompt"):
        Scheduler(m, params, SchedulerConfig(buckets=None))
    with pytest.raises(ValueError, match="kv layout"):
        Scheduler(m, params, SchedulerConfig(kv="sparse"))
    # the pallas backend takes one global key + static t: no per-request
    # streams (reference and fused both work — see the serving tests)
    with pytest.raises(ValueError, match="pallas"):
        Scheduler(m, params, policy=ft.get_policy("crt1", ber=1e-3),
                  ft_backend="pallas")
    # fail-fast request validation: duplicate rids (results and fault
    # streams are keyed by rid) and per-request caps beyond slot capacity
    sched = Scheduler(m, params, SchedulerConfig(
        max_batch=2, buckets=(8,), max_new_tokens=4))
    with pytest.raises(ValueError, match="duplicate"):
        sched.run([Request(rid=1, tokens=_prompt(4, cfg.vocab, 0),
                           max_new_tokens=4),
                   Request(rid=1, tokens=_prompt(4, cfg.vocab, 1),
                           max_new_tokens=4)])
    with pytest.raises(ValueError, match="capacity"):
        sched.run([Request(rid=1, tokens=_prompt(4, cfg.vocab, 0),
                           max_new_tokens=9)])
    # a single request can never need more KV blocks than the pool holds
    tiny = Scheduler(m, params, SchedulerConfig(
        max_batch=2, buckets=(8,), max_new_tokens=4, block_size=2,
        n_blocks=3))
    with pytest.raises(ValueError, match="blocks"):
        tiny.run([Request(rid=1, tokens=_prompt(8, cfg.vocab, 0),
                          max_new_tokens=4)])


def test_paged_matches_dense(danube):
    """The paged KV cache is a pure layout change: the same workload through
    kv='paged' and kv='dense' yields bit-identical tokens, even with a
    deliberately tight block pool that forces requests to wait for blocks."""
    cfg, m, params = danube
    mk = lambda: [Request(rid=i, tokens=_prompt(3 + 2 * (i % 3), cfg.vocab,
                                                20 + i),
                          max_new_tokens=5 + (i % 2)) for i in range(5)]
    outs = {}
    for kv in ("dense", "paged"):
        scfg = SchedulerConfig(max_batch=2, buckets=(8,), max_new_tokens=6,
                               decode_chunk=3, kv=kv)
        outs[kv] = Scheduler(m, params, scfg).run(mk())
    for i in range(5):
        assert outs["paged"][i].generated == outs["dense"][i].generated
    # tight pool: room for roughly one request's blocks at a time
    probe = Scheduler(m, params, SchedulerConfig(
        max_batch=2, buckets=(8,), max_new_tokens=6, kv="paged",
        block_size=4))
    need1 = probe._blocks_needed(8, 6)
    tight = Scheduler(m, params, SchedulerConfig(
        max_batch=2, buckets=(8,), max_new_tokens=6, decode_chunk=3,
        kv="paged", block_size=4, n_blocks=1 + need1 + 1))
    out_t = tight.run(mk())
    for i in range(5):
        assert out_t[i].generated == outs["dense"][i].generated
    assert tight.stats.blocks_in_use_peak <= need1 + 1


def test_weight_faults_serving(danube):
    """PR 3's weight_faults=False restriction is lifted: per-row weight
    flip streams give each request its own faulty view of the shared SRAM.
    Tokens stay a pure function of rid (alone == crowded), and the fused
    backend reproduces the reference stream bit-for-bit."""
    cfg, m, params = danube
    policy = ft.get_policy("crt1", ber=3e-3, weight_faults=True)
    scfg = SchedulerConfig(max_batch=2, buckets=(8,), max_new_tokens=6,
                           decode_chunk=3)
    alone = Scheduler(m, params, scfg, policy=policy).run(
        [Request(rid=7, tokens=_prompt(5, cfg.vocab, 7), max_new_tokens=6)])
    crowd = [Request(rid=7, tokens=_prompt(5, cfg.vocab, 7),
                     max_new_tokens=6),
             Request(rid=8, tokens=_prompt(3, cfg.vocab, 8),
                     max_new_tokens=6)]
    crowded = Scheduler(m, params, scfg, policy=policy).run(crowd)
    assert alone[7].generated == crowded[7].generated
    fused = Scheduler(m, params, scfg, policy=policy,
                      ft_backend="fused").run(
        [Request(rid=7, tokens=_prompt(5, cfg.vocab, 7), max_new_tokens=6)])
    assert fused[7].generated == alone[7].generated


def test_exact_mode_recurrent_and_enc_dec():
    """buckets=None (exact-length prefill) + paged KV admits the families
    bucketed prefill rejects: recurrent/SSM state and encoder-decoder
    cross-attention, with per-slot encoder lengths."""
    ssm_cfg = get_config("mamba2-2.7b", reduced=True)
    sm = build(ssm_cfg)
    sparams = sm.init(jax.random.PRNGKey(0))
    scfg = SchedulerConfig(max_batch=2, buckets=None, max_prompt=8,
                           max_new_tokens=5, decode_chunk=2)
    mk = lambda: [Request(rid=i, tokens=_prompt(4 + 2 * (i % 2),
                                                ssm_cfg.vocab, i),
                          max_new_tokens=5) for i in range(3)]
    crowded = Scheduler(sm, sparams, scfg).run(mk())
    assert all(len(r.generated) == 5 for r in crowded.values())
    alone = Scheduler(sm, sparams, scfg).run([mk()[0]])
    assert alone[0].generated == crowded[0].generated

    ed_cfg = get_config("seamless-m4t-medium", reduced=True)
    em = build(ed_cfg)
    eparams = em.init(jax.random.PRNGKey(0))
    frames = lambda n, s: jax.random.normal(
        jax.random.PRNGKey(90 + s), (n, ed_cfg.d_model), jnp.float32)
    ereqs = lambda: [Request(rid=i, tokens=_prompt(4, ed_cfg.vocab, 40 + i),
                             max_new_tokens=4,
                             extras={"frames": frames(5 + i, i)})
                     for i in range(3)]
    ecrowd = Scheduler(em, eparams, scfg).run(ereqs())
    assert all(len(r.generated) == 4 for r in ecrowd.values())
    ealone = Scheduler(em, eparams, scfg).run([ereqs()[1]])
    assert ealone[1].generated == ecrowd[1].generated


def test_recurrent_paged_matches_dense():
    """kv='dense' is legal for recurrent families too (their R/S state rows
    are dense per-slot either way), which restores the bit-exactness oracle:
    the same workload through kv='paged' and kv='dense' must emit identical
    tokens for a config that mixes attention and recurrent blocks."""
    cfg = get_config("recurrentgemma-9b", reduced=True)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    scfg = lambda kv: SchedulerConfig(max_batch=2, buckets=None, max_prompt=6,
                                      max_new_tokens=4, decode_chunk=2, kv=kv)
    mk = lambda: [Request(rid=i, tokens=_prompt(4 + (i % 2), cfg.vocab,
                                                60 + i), max_new_tokens=4)
                  for i in range(3)]
    outs = {kv: Scheduler(m, params, scfg(kv)).run(mk())
            for kv in ("dense", "paged")}
    for i in range(3):
        assert outs["paged"][i].generated == outs["dense"][i].generated


def test_scheduler_vision_frontend():
    cfg = get_config("paligemma-3b", reduced=True)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    reqs = [Request(rid=i, tokens=_prompt(4 + i, cfg.vocab, i),
                    max_new_tokens=5,
                    extras={"patch_embeds": jax.random.normal(
                        jax.random.PRNGKey(50 + i),
                        (cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)})
            for i in range(3)]
    sched = Scheduler(m, params, SchedulerConfig(
        max_batch=2, buckets=(8,), max_new_tokens=5, decode_chunk=2))
    out = sched.run(reqs)
    assert all(len(r.generated) == 5 for r in out.values())
