"""Batched DSE engine: q-EI proposal loop, batch oracles, vmapped accuracy.

Covers the ISSUE-3 guarantees: batch_size=1 is the exact sequential
algorithm, batch_size>1 stays feasible/deduped/pruned on a fixed seed, the
numpy-broadcast area/perf/IO batch oracles match the scalar models, and the
vmapped fault-injection oracle is bit-identical to the looped n_rep path.
"""
import jax
import numpy as np
import pytest

from repro.core import area as A
from repro.core import bayesopt as B
from repro.core import perfmodel as P
from repro.core.pipeline import (batch_area_overhead, batch_perf_bw,
                                 _policy_from_cfg, optimize)
from repro.ft import get_policy


def synthetic_eval(cfg):
    prot = cfg["s_th"] * 4 + cfg["ib_th"] * 0.08 + cfg["nb_th"] * 0.3
    area = prot * (0.5 if cfg["pe_policy"] == "configurable" else 1.0)
    area += cfg["dot_size"] / 512
    acc = min(0.70 + prot * 0.25, 0.78)
    perf = 0.0 if cfg["dot_size"] >= 16 else 0.2
    bw = cfg["s_th"]
    return B.EvalResult(area=area, acc=acc, perf_loss=perf, bw_loss=bw)


def strict_eval(cfg):
    prot = cfg["s_th"] * 4 + cfg["ib_th"] * 0.08 + cfg["nb_th"] * 0.3
    return B.EvalResult(area=prot, acc=0.70 + prot * 0.08,
                        perf_loss=0.0, bw_loss=0.0)


# ---------------------------------------------------------------- BO loop --
def test_batch_size_one_is_sequential():
    """Supplying evaluate_batch must not change the sequential stream."""
    cons = B.Constraints(acc_min=0.75)
    plain = B.bayes_design_opt(B.table1_space(), synthetic_eval, cons,
                               iter_max_step=48, seed=0)
    with_batch_fn = B.bayes_design_opt(
        B.table1_space(), synthetic_eval, cons, iter_max_step=48, seed=0,
        batch_size=1,
        evaluate_batch=lambda cfgs: [synthetic_eval(c) for c in cfgs])
    assert [c for c, _ in plain.history] == [c for c, _ in
                                             with_batch_fn.history]
    assert plain.best == with_batch_fn.best
    assert plain.pruned == with_batch_fn.pruned


def test_batched_feasible_no_worse_than_sequential_fixed_seed():
    cons = B.Constraints(acc_min=0.75)
    seq = B.bayes_design_opt(B.table1_space(), synthetic_eval, cons,
                             iter_max_step=48, seed=3)
    bat = B.bayes_design_opt(B.table1_space(), synthetic_eval, cons,
                             iter_max_step=48, seed=3, batch_size=4)
    assert bat.best is not None
    assert bat.best_eval.feasible(cons)
    assert bat.best_eval.area <= seq.best_eval.area + 1e-12


def test_batch_dedup_and_pruning_honored():
    cons = B.Constraints(acc_min=0.80, perf_max=0.5, bw_max=0.5)
    batches = []

    def eval_batch(cfgs):
        batches.append([tuple(sorted((k, str(v)) for k, v in c.items()))
                        for c in cfgs])
        return [strict_eval(c) for c in cfgs]

    total_pruned = 0
    for seed in range(4):
        res = B.bayes_design_opt(B.table1_space(), strict_eval, cons,
                                 iter_max_step=80, n_init=30,
                                 n_candidates=512, seed=seed, batch_size=4,
                                 evaluate_batch=eval_batch)
        total_pruned += res.pruned
        assert res.evaluations <= 80
        evaluated = [tuple(sorted((k, str(v)) for k, v in c.items()))
                     for c, _ in res.history]
        assert len(evaluated) == len(set(evaluated))  # dedup across run
    assert total_pruned > 0  # dominance pruning fires inside batched rounds
    assert all(len(b) <= 4 for b in batches)
    assert any(len(b) > 1 for b in batches)  # batching actually happened


def test_evaluate_or_evaluate_batch_required():
    with pytest.raises(ValueError):
        B.bayes_design_opt(B.table1_space(), None, B.Constraints(acc_min=0.5))


# ------------------------------------------------- batched analytic oracles
def test_batch_oracles_match_scalar_models():
    rng = np.random.default_rng(0)
    space = B.table1_space()
    cfgs = [{p.name: p.values[rng.integers(len(p.values))] for p in space}
            for _ in range(25)]
    pols = [_policy_from_cfg(c, 1e-3) for c in cfgs]
    pols += [get_policy("arch", ber=1e-3), get_policy("alg", ber=1e-3),
             get_policy("crt2", ber=1e-3), get_policy("base")]
    layers = P.lm_layer_gemms(4, 256, 1024, 8, 32, 8, seq=128)
    areas = batch_area_overhead(pols, 32)
    perfs, bws = batch_perf_bw(pols, layers, 32)
    for i, p in enumerate(pols):
        ref_area = A.array_area(32, p.circuit.nb_th, p.algorithm.q_scale,
                                p.circuit.pe_policy,
                                dot_size=p.arch.dot_size,
                                ib_th=p.circuit.ib_th)["overhead"]
        dla = P.DlaConfig(array_dim=32, dot_size=p.arch.dot_size,
                          data_reuse=p.arch.data_reuse)
        ref_perf = P.perf_loss(layers, dla, p.perf_kind,
                               s_th=p.algorithm.s_th)
        ref_bw = P.io_bytes(layers, dla, p.perf_kind,
                            s_th=p.algorithm.s_th)["extra_over_weights"]
        assert np.isclose(areas[i], ref_area, rtol=1e-12)
        assert np.isclose(perfs[i], ref_perf, rtol=1e-12)
        assert np.isclose(bws[i], ref_bw, rtol=1e-12)


def test_optimize_batched_pipeline():
    """End-to-end driver with a cheap deterministic accuracy oracle."""
    layers = P.lm_layer_gemms(2, 128, 512, 4, 32, 4, seq=64)

    def fake_acc(pol):
        prot = (pol.algorithm.s_th * 4 + pol.circuit.ib_th * 0.08
                + pol.circuit.nb_th * 0.3)
        return min(0.70 + prot * 0.25, 0.78)

    calls = {"batched": 0}

    def fake_acc_batch(pols):
        calls["batched"] += len(pols)
        return [fake_acc(p) for p in pols]

    cons = B.Constraints(acc_min=0.75, perf_max=2.0, bw_max=2.0)
    seq = optimize(fake_acc, layers, cons, 1e-3, iter_max_step=24, seed=1)
    bat = optimize(fake_acc, layers, cons, 1e-3, iter_max_step=24, seed=1,
                   batch_size=6, acc_oracle_batch=fake_acc_batch)
    assert calls["batched"] > 0
    assert (seq.policy is None) == (bat.policy is None)  # same feasibility
    if bat.policy is not None:
        assert bat.dse.best_eval.feasible(cons)


# ----------------------------------------------------- vmapped CNN oracle --
@pytest.fixture(scope="module")
def tiny_oracle():
    from repro.core.evaluate import CnnOracle
    from repro.models.cnn import CNNConfig, train_cnn
    cfg = CNNConfig(channels=(8,), hw=8)
    params, _ = train_cnn(jax.random.PRNGKey(0), cfg, steps=60)
    return CnnOracle(params, cfg, n_eval=96, n_rep=2, noise=0.8)


POLICIES = [
    get_policy("cl", ber=8e-3, s_th=0.1, ib_th=3, nb_th=1, q_scale=4),
    get_policy("cl", ber=4e-3, s_th=0.05, ib_th=2, nb_th=2, q_scale=7),
    get_policy("crt2", ber=4e-3),
]


def test_vmapped_accuracy_bit_identical_to_looped(tiny_oracle):
    for pol in POLICIES:
        looped = tiny_oracle._accuracy_looped(pol)
        vmapped = tiny_oracle.accuracy(pol)
        assert vmapped == looped  # exact: integer datapath under vmap


def test_accuracy_batch_bit_identical_to_single(tiny_oracle):
    batched = tiny_oracle.accuracy_batch(POLICIES)
    singles = [tiny_oracle.accuracy(p) for p in POLICIES]
    assert batched == singles  # exact, including cross-candidate vmap lanes


def test_accuracy_batch_handles_clean_and_mixed(tiny_oracle):
    pols = [None, POLICIES[0]]
    batched = tiny_oracle.accuracy_batch(pols)
    assert batched[0] == tiny_oracle.accuracy(None)
    assert batched[1] == tiny_oracle.accuracy(POLICIES[0])


def test_sens_cache_keyed_on_n_rep(tiny_oracle):
    sens = tiny_oracle.layer_sensitivity(8e-3)
    assert (8e-3, 0, tiny_oracle.n_rep) in tiny_oracle._sens_cache
    assert sens == tiny_oracle.layer_sensitivity(8e-3)  # cache hit
