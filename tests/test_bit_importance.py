from repro.core import bit_importance as BI


def test_picks_cheapest_meeting_target():
    calls = []

    def oracle(ib, nb):
        calls.append((ib, nb))
        return 0.5 + 0.05 * ib + 0.04 * nb  # monotone accuracy

    table = {(ib, nb): ib + 3 * nb
             for ib in range(0, 9) for nb in range(0, ib + 1)}
    best = BI.get_bit_config(oracle, acc_target=0.80, bits=8,
                             cost_table=table)
    assert best is not None
    assert best.acc >= 0.80
    # cheapest feasible in this synthetic: maximize ib before nb
    for (ib, nb), cost in table.items():
        if nb <= ib and 0.5 + 0.05 * ib + 0.04 * nb >= 0.80:
            assert best.cost <= cost


def test_pruning_skips_dominated_failures():
    evals = []

    def oracle(ib, nb):
        evals.append((ib, nb))
        return 1.0 if (ib >= 6 and nb >= 2) else 0.0

    table = {(ib, nb): ib + nb for ib in range(0, 9)
             for nb in range(0, ib + 1)}
    best = BI.get_bit_config(oracle, acc_target=0.5, bits=8,
                             cost_table=table)
    assert best is not None and best.ib_th >= 6 and best.nb_th >= 2
    total = sum(1 for ib in range(1, 9) for nb in range(0, ib + 1))
    assert len(evals) < total  # pruning actually skipped some


def test_infeasible_returns_none():
    best = BI.get_bit_config(lambda ib, nb: 0.0, acc_target=0.9, bits=4,
                             cost_table={(i, n): 1.0 for i in range(5)
                                         for n in range(i + 1)})
    assert best is None
