"""SSD and RG-LRU sequence-vs-recurrent equivalence (the property that makes
long_500k decode valid)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.ssm import ssd_chunked


def seq_ref(x, dt, A, Bm, Cm):
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    state = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        dA = jnp.exp(-dt[:, t] * A[None, :])
        state = state * dA[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], x[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t], state))
    return jnp.stack(ys, 1), state


@settings(max_examples=10, deadline=None)
@given(chunk=st.sampled_from([2, 4, 8, 16]), seed=st.integers(0, 100))
def test_ssd_chunked_matches_sequential(chunk, seed):
    B, S, H, P, N = 2, 16, 3, 4, 5
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = jnp.abs(jax.random.normal(ks[2], (H,))) + 0.1
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(jax.random.fold_in(ks[3], 1), (B, S, N))
    y, s = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    yr, sr = seq_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=2e-4)


def test_rglru_recurrence_matches_loop():
    from repro.models.rglru import _recurrence
    B, S, W = 2, 12, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W)))
    bx = jax.random.normal(ks[1], (B, S, W))
    h = _recurrence(a, bx)
    ref = []
    cur = jnp.zeros((B, W))
    for t in range(S):
        cur = a[:, t] * cur + bx[:, t]
        ref.append(cur)
    np.testing.assert_allclose(np.asarray(h), np.asarray(jnp.stack(ref, 1)),
                               atol=1e-5)


def test_ssd_padding_equivalence():
    """Padding to a chunk multiple must not change outputs (dt=0 padding)."""
    B, S, H, P, N = 1, 10, 2, 4, 3
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = jnp.abs(jax.random.normal(ks[2], (H,))) + 0.1
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(jax.random.fold_in(ks[3], 1), (B, S, N))
    pad = 6
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Bp = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
    Cp = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y1, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=2)
    y2, _ = ssd_chunked(xp, dtp, A, Bp, Cp, chunk=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2[:, :S]),
                               atol=2e-4)
