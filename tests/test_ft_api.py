"""The repro.ft public API: registry round-trip, pytree/vmap semantics,
bit-exact parity with the legacy ``ft_linear`` implementation (frozen below
as the oracle), and the pallas backend."""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ft
from repro.core import faults, quantization as Q
from repro.core.flexhyca import FTConfig, clean_linear

POLICY_NAMES = ("base", "crt1", "crt2", "crt3", "arch", "alg", "cl")


# --------------------------------------------------------------------------
# Frozen copy of the seed ``repro.core.flexhyca.ft_linear`` (pre-registry):
# the parity oracle pinning the historical bit-exact semantics.
# --------------------------------------------------------------------------
def _legacy_strategy_protect(cfg: FTConfig, important, n: int):
    if cfg.strategy == "base":
        return jnp.zeros((n,), jnp.int32), False
    if cfg.strategy.startswith("crt"):
        k = int(cfg.strategy[3:])
        return jnp.full((n,), k, jnp.int32), False
    if cfg.strategy in ("arch", "alg"):
        return jnp.zeros((n,), jnp.int32), True
    if cfg.strategy == "cl":
        imp = jnp.zeros((n,), bool) if important is None else important
        return jnp.where(imp, cfg.ib_th, cfg.nb_th).astype(jnp.int32), False
    raise ValueError(cfg.strategy)


@partial(jax.jit, static_argnames=("cfg", "layer_protected"))
def _legacy_ft_linear(key, x, w, cfg: FTConfig, important=None,
                      layer_protected: bool = True):
    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1])
    kw, ka, kd = jax.random.split(key, 3)

    q_scale = cfg.q_scale if cfg.strategy == "cl" else 0
    xq, sx = Q.quantize(x2)
    wq, sw = Q.quantize(w)
    if cfg.ber > 0 and cfg.weight_faults:
        wq_f = faults.inject_weight_faults(kw, wq, cfg.ber)
    else:
        wq_f = wq
    acc = Q.saturate(jnp.matmul(xq, wq_f, preferred_element_type=jnp.int32))
    t = Q.choose_trunc_lsb(jnp.max(jnp.abs(acc)), q_scale=q_scale)
    yq = Q.truncate_acc(acc, t)

    protect, whole_layer_tmr = _legacy_strategy_protect(cfg, important,
                                                        w.shape[1])
    if cfg.ber > 0:
        if whole_layer_tmr and layer_protected:
            yq_f = faults.inject_output_faults(
                ka, yq, cfg.ber,
                protect_top=jnp.full((w.shape[1],), 8, jnp.int32))
        else:
            yq_f = faults.inject_output_faults(ka, yq, cfg.ber,
                                               protect_top=protect)
    else:
        yq_f = yq

    if cfg.strategy == "cl" and cfg.ber > 0 and important is not None:
        acc_d = Q.saturate(jnp.matmul(xq, wq,
                                      preferred_element_type=jnp.int32))
        yq_d = Q.truncate_acc(acc_d, t)
        yq_d = faults.inject_output_faults(
            kd, yq_d, cfg.ber,
            protect_top=jnp.full((w.shape[1],), cfg.ib_th, jnp.int32))
        yq_f = jnp.where(important[None, :], yq_d, yq_f)

    scale = sx * sw * (2.0 ** t.astype(jnp.float32))
    y = yq_f.astype(jnp.float32) * scale
    return y.reshape(*orig_shape[:-1], w.shape[1])


@pytest.fixture(scope="module")
def xw():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 48))
    w = jax.random.normal(jax.random.PRNGKey(1), (48, 32))
    return x, w


@pytest.fixture(scope="module")
def imp():
    return jnp.zeros((32,), bool).at[:8].set(True)


# ----------------------------------------------------------------- parity --
@pytest.mark.parametrize("name", POLICY_NAMES)
@pytest.mark.parametrize("ber", (0.0, 0.01))
def test_protect_linear_matches_legacy(xw, imp, name, ber):
    """ft.protect_linear must be bit-exact with the seed implementation for
    every registered paper design."""
    x, w = xw
    key = jax.random.PRNGKey(7)
    cfg = FTConfig(ber=ber, strategy=name)
    y_new = ft.protect_linear(key, x, w, ft.from_ftconfig(cfg), important=imp)
    y_old = _legacy_ft_linear(key, x, w, cfg, important=imp)
    np.testing.assert_array_equal(np.asarray(y_new), np.asarray(y_old))


@pytest.mark.parametrize("layer_protected", (True, False))
def test_parity_whole_layer_tmr(xw, layer_protected):
    x, w = xw
    key = jax.random.PRNGKey(11)
    cfg = FTConfig(ber=0.005, strategy="arch", weight_faults=False)
    y_new = ft.protect_linear(key, x, w, ft.from_ftconfig(cfg),
                              layer_protected=layer_protected)
    y_old = _legacy_ft_linear(key, x, w, cfg,
                              layer_protected=layer_protected)
    np.testing.assert_array_equal(np.asarray(y_new), np.asarray(y_old))


def test_parity_tuned_cl(xw, imp):
    x, w = xw
    key = jax.random.PRNGKey(13)
    cfg = FTConfig(ber=0.02, strategy="cl", s_th=0.25, ib_th=4, nb_th=2,
                   q_scale=4, weight_faults=False)
    y_new = ft.protect_linear(key, x, w, ft.from_ftconfig(cfg), important=imp)
    y_old = _legacy_ft_linear(key, x, w, cfg, important=imp)
    np.testing.assert_array_equal(np.asarray(y_new), np.asarray(y_old))


def test_ft_linear_shim_matches_legacy(xw, imp):
    from repro.core.flexhyca import ft_linear
    x, w = xw
    key = jax.random.PRNGKey(17)
    cfg = FTConfig(ber=0.01, strategy="cl")
    with pytest.deprecated_call():
        y_shim = ft_linear(key, x, w, cfg, important=imp)
    y_old = _legacy_ft_linear(key, x, w, cfg, important=imp)
    np.testing.assert_array_equal(np.asarray(y_shim), np.asarray(y_old))


# --------------------------------------------------------------- registry --
def test_registry_roundtrip():
    pol = ft.ProtectionPolicy(
        name="fat-test", arch=ft.ArchLayer(recompute=True),
        circuit=ft.CircuitLayer(ib_th=5, nb_th=2))
    try:
        ft.register_policy(pol)
        assert ft.get_policy("fat-test") == pol
        assert "fat-test" in ft.list_policies()
        with pytest.raises(ValueError, match="already registered"):
            ft.register_policy(pol)
        ft.register_policy(pol.tune(nb_th=3), overwrite=True)
        assert ft.get_policy("fat-test").circuit.nb_th == 3
    finally:
        ft.registry._REGISTRY.pop("fat-test", None)


def test_get_policy_unknown_name():
    with pytest.raises(KeyError, match="unknown protection policy"):
        ft.get_policy("does-not-exist")


def test_paper_designs_registered():
    for name in POLICY_NAMES:
        assert name in ft.list_policies()


def test_tune_routes_fields_to_components():
    p = ft.get_policy("cl", ber=1e-3, ib_th=4, s_th=0.2, dot_size=16)
    assert p.ber == 1e-3
    assert p.circuit.ib_th == 4
    assert p.algorithm.s_th == 0.2
    assert p.arch.dot_size == 16
    with pytest.raises(TypeError, match="unknown protection-policy field"):
        ft.get_policy("cl", bogus_knob=1)


def test_perf_kind_derived_from_structure():
    kinds = {n: ft.get_policy(n).perf_kind for n in POLICY_NAMES}
    assert kinds == {"base": "base", "crt1": "crt", "crt2": "crt",
                     "crt3": "crt", "arch": "arch", "alg": "alg", "cl": "cl"}


# ----------------------------------------------------------------- pytree --
def test_policy_is_pytree_with_ber_leaf():
    p = ft.get_policy("cl", ber=0.25)
    leaves, treedef = jax.tree_util.tree_flatten(p)
    assert leaves == [0.25]
    p2 = jax.tree_util.tree_unflatten(treedef, [0.5])
    assert p2 == dataclasses.replace(p, ber=0.5)


def test_vmap_over_ber_axis(xw, imp):
    """One executable sweeps the BER axis: row 0 (BER 0) is clean, damage
    grows along the axis."""
    x, w = xw
    key = jax.random.PRNGKey(19)
    bers = jnp.array([0.0, 1e-3, 5e-2], jnp.float32)
    pols = ft.get_policy("cl", weight_faults=False, q_scale=0).with_ber(bers)
    ys = jax.vmap(
        lambda p: ft.protect_linear(key, x, w, p, important=imp))(pols)
    assert ys.shape == (3, 64, 32)
    ref = clean_linear(x, w, q_scale=0)

    def dmg(y):
        return float(jnp.sqrt(jnp.mean((y - ref) ** 2)))

    assert dmg(ys[0]) < 1e-6          # BER 0 row is exactly clean
    assert dmg(ys[0]) < dmg(ys[1]) < dmg(ys[2])


def test_scan_over_ber_axis(xw, imp):
    x, w = xw
    key = jax.random.PRNGKey(23)
    pols = ft.get_policy("base").with_ber(jnp.array([0.0, 1e-2], jnp.float32))
    _, ys = jax.lax.scan(
        lambda c, p: (c, ft.protect_linear(key, x, w, p)), 0, pols)
    assert ys.shape == (2, 64, 32)
    # and the static-BER call is bit-identical to the scanned row
    y_static = ft.protect_linear(key, x, w, ft.get_policy("base", ber=1e-2))
    np.testing.assert_array_equal(np.asarray(ys[1]), np.asarray(y_static))


# --------------------------------------------------------------- backends --
def test_pallas_backend_clean_parity(xw, imp):
    """Both backends are bit-exact at BER 0 (same quantized datapath)."""
    x, w = xw
    key = jax.random.PRNGKey(29)
    for name in ("base", "cl", "crt2"):
        pol = ft.get_policy(name, weight_faults=False)
        # ftlint: disable=FTL001 -- parity test: one key for all backends
        y_ref = ft.protect_linear(key, x, w, pol, important=imp,
                                  backend="reference")
        y_pal = ft.protect_linear(key, x, w, pol, important=imp,
                                  backend="pallas")
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                                   rtol=1e-6)


def test_pallas_backend_protection_helps(xw):
    """Under fault the backends draw from different RNG streams; the
    protection ordering (more protected bits => less damage) must hold."""
    x, w = xw
    key = jax.random.PRNGKey(31)
    ref = clean_linear(x, w)

    def dmg(y):
        return float(jnp.sqrt(jnp.mean((y - ref) ** 2)))

    d = {}
    for name in ("base", "crt3"):
        pol = ft.get_policy(name, ber=0.02, weight_faults=False)
        # ftlint: disable=FTL001 -- paired run: identical fault stream
        d[name] = dmg(ft.protect_linear(key, x, w, pol, backend="pallas"))
    assert d["crt3"] < d["base"]


def test_pallas_whole_layer_tmr(xw):
    x, w = xw
    key = jax.random.PRNGKey(37)
    pol = ft.get_policy("arch", ber=0.02, weight_faults=False)
    ref = clean_linear(x, w)

    def dmg(y):
        return float(jnp.sqrt(jnp.mean((y - ref) ** 2)))

    prot = dmg(ft.protect_linear(key, x, w, pol, backend="pallas",
                                 layer_protected=True))
    # ftlint: disable=FTL001 -- paired run: identical fault stream
    unprot = dmg(ft.protect_linear(key, x, w, pol, backend="pallas",
                                   layer_protected=False))
    assert prot < unprot


def test_unknown_backend_raises(xw):
    x, w = xw
    with pytest.raises(ValueError, match="unknown backend"):
        ft.protect_linear(jax.random.PRNGKey(0), x, w, ft.get_policy("base"),
                          backend="cuda")


def test_pallas_under_jit_needs_calibrated_t(xw):
    """Inside jit the pallas backend cannot self-calibrate (its kernel takes
    t statically): without t it must fail with guidance, with a calibrated t
    it must match the eager pallas result."""
    x, w = xw
    key = jax.random.PRNGKey(41)
    pol = ft.get_policy("crt2", ber=0.01, weight_faults=False)

    with pytest.raises(ValueError, match="pre-calibrated truncation LSB"):
        jax.jit(lambda k, a, b: ft.protect_linear(k, a, b, pol,
                                                  backend="pallas"))(key, x, w)

    t = ft.calibrate_t(x, w, q_scale=pol.algorithm.q_scale)
    y_jit = jax.jit(lambda k, a, b: ft.protect_linear(
        k, a, b, pol, backend="pallas", t=t))(key, x, w)
    y_eager = ft.protect_linear(key, x, w, pol, backend="pallas")
    # jit fuses the final rescale differently; integer datapath is identical
    np.testing.assert_allclose(np.asarray(y_jit), np.asarray(y_eager),
                               rtol=1e-5)


def test_ftctx_pallas_backend_with_t_table(xw):
    """FTCtx threads backend/t through the model-side linear() wrapper, so
    jitted model code can run the kernel path with a calibration table."""
    from repro.models.common import FTCtx, linear
    x, w = xw
    pol = ft.get_policy("crt1", ber=0.005, weight_faults=False)
    t = ft.calibrate_t(x, w)
    ftc = FTCtx(pol, jax.random.PRNGKey(43), backend="pallas",
                t={"site": t})
    y = jax.jit(lambda a, b: linear(a, b, ftc=ftc, name="site"))(x, w)
    assert y.shape == (64, 32)
    assert bool(jnp.isfinite(y).all())
