import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.moe import _local_moe, init as moe_init


def logits(p, x):
    # `apply` computes router logits through common.linear (fault layer)
    # before dispatch; these unit tests exercise the dispatch alone
    return x.astype(jnp.float32) @ p["router"]


def setup(cap_factor=8.0):
    cfg = get_config("qwen3-moe-235b-a22b", reduced=True)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cap_factor))
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    return cfg, p, x


def per_token_ref(cfg, p, x):
    m = cfg.moe
    x2 = x.reshape(-1, cfg.d_model)
    probs = jax.nn.softmax(x2 @ p["router"], -1)
    tw, ti = jax.lax.top_k(probs, m.top_k)
    tw = tw / tw.sum(-1, keepdims=True)
    out = jnp.zeros_like(x2)
    for t in range(x2.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for kk in range(m.top_k):
            e = int(ti[t, kk])
            h = jax.nn.silu(x2[t] @ p["wi"][e]) * (x2[t] @ p["wg"][e])
            acc += tw[t, kk] * (h @ p["wo"][e])
        out = out.at[t].set(acc)
    return out.reshape(x.shape)


def test_moe_matches_per_token_reference():
    cfg, p, x = setup()
    m = cfg.moe
    T = x.shape[0] * x.shape[1]
    cap = int(8.0 * T * m.top_k / m.n_experts) + 1
    y, _ = _local_moe(x, logits(p, x), p["wi"], p["wg"], p["wo"], e0=0,
                      n_experts=m.n_experts, top_k=m.top_k, capacity=cap,
                      act_name=cfg.act)
    ref = per_token_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)


def test_expert_partitioning_sums_to_whole():
    """Partial-sum EP invariant: sum of per-shard partial outputs over
    disjoint expert ranges == single-shard full output."""
    cfg, p, x = setup()
    m = cfg.moe
    T = x.shape[0] * x.shape[1]
    cap = int(8.0 * T * m.top_k / m.n_experts) + 1
    full, _ = _local_moe(x, logits(p, x), p["wi"], p["wg"], p["wo"], e0=0,
                         n_experts=m.n_experts, top_k=m.top_k, capacity=cap,
                         act_name=cfg.act)
    E_half = m.n_experts // 2
    y0, _ = _local_moe(x, logits(p, x), p["wi"][:E_half], p["wg"][:E_half],
                       p["wo"][:E_half], e0=0, n_experts=m.n_experts,
                       top_k=m.top_k, capacity=cap, act_name=cfg.act)
    y1, _ = _local_moe(x, logits(p, x), p["wi"][E_half:], p["wg"][E_half:],
                       p["wo"][E_half:], e0=E_half, n_experts=m.n_experts,
                       top_k=m.top_k, capacity=cap, act_name=cfg.act)
    np.testing.assert_allclose(np.asarray(y0 + y1), np.asarray(full),
                               atol=1e-4)


def test_capacity_drops_tokens():
    cfg, p, x = setup()
    m = cfg.moe
    tiny_cap = 1
    y, _ = _local_moe(x, logits(p, x), p["wi"], p["wg"], p["wo"], e0=0,
                      n_experts=m.n_experts, top_k=m.top_k,
                      capacity=tiny_cap, act_name=cfg.act)
    ref = per_token_ref(cfg, p, x)
    assert float(jnp.abs(y - ref).max()) > 1e-3  # drops => different output
    assert bool(jnp.isfinite(y).all())


def test_aux_loss_near_one_for_uniform_router():
    cfg, p, x = setup()
    m = cfg.moe
    p = dict(p, router=jnp.zeros_like(p["router"]))
    T = x.shape[0] * x.shape[1]
    cap = int(8.0 * T * m.top_k / m.n_experts) + 1
    _, lb = _local_moe(x, logits(p, x), p["wi"], p["wg"], p["wo"], e0=0,
                       n_experts=m.n_experts, top_k=m.top_k, capacity=cap,
                       act_name=cfg.act)
    # balanced probs: lb == E * sum(f_e * 1/E) == 1 (f sums to 1)
    assert abs(float(lb[0]) - 1.0) < 0.2
