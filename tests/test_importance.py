import jax
import jax.numpy as jnp
import numpy as np

from repro.core.importance import Probe, neuron_importance


def tiny_mlp_apply(params, batch, probe):
    x = batch["x"]
    h = jax.nn.relu(x @ params["w1"])
    h = probe.tag("h", h)
    return h @ params["w2"]


def loss_fn(out, batch):
    return jnp.mean((out - batch["y"]) ** 2)


def make_params(key, boost_channel=3):
    k1, k2 = jax.random.split(key)
    w1 = jax.random.normal(k1, (8, 16)) * 0.3
    w2 = jax.random.normal(k2, (16, 4)) * 0.3
    # channel `boost_channel` feeds the output with a huge weight => its
    # activation gradient dominates => it must rank as important
    w2 = w2.at[boost_channel].set(10.0)
    return {"w1": w1, "w2": w2}


def batches(n=4):
    out = []
    for i in range(n):
        k = jax.random.PRNGKey(100 + i)
        out.append({"x": jax.random.normal(k, (32, 8)),
                    "y": jax.random.normal(jax.random.fold_in(k, 1), (32, 4))})
    return out


def test_high_gradient_channel_ranks_top():
    params = make_params(jax.random.PRNGKey(0), boost_channel=3)
    res = neuron_importance(tiny_mlp_apply, params, batches(), loss_fn)
    assert "h" in res.scores
    assert int(np.argmax(res.scores["h"])) == 3


def test_select_uniform_fraction():
    params = make_params(jax.random.PRNGKey(0))
    res = neuron_importance(tiny_mlp_apply, params, batches(), loss_fn)
    masks = res.select(0.25, policy="uniform")
    assert masks["h"].sum() == 4  # 25% of 16


def test_select_global_contains_boosted():
    params = make_params(jax.random.PRNGKey(0), boost_channel=7)
    res = neuron_importance(tiny_mlp_apply, params, batches(), loss_fn)
    masks = res.select(0.1, policy="global")
    assert masks["h"][7]


def test_probe_passthrough():
    p = Probe(None)
    x = jnp.ones((2, 3))
    assert (p.tag("a", x) == x).all()
    assert p.shapes["a"] == (2, 3)


def test_probe_tap_addition():
    taps = {"a": jnp.full((2, 3), 2.0)}
    p = Probe(taps)
    assert (p.tag("a", jnp.ones((2, 3))) == 3.0).all()
