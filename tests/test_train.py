"""Training loop: loss goes down, checkpoint/restart is bit-exact, straggler
mitigation triggers, gradient accumulation is consistent."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import RunConfig, ShapeConfig
from repro.models import build
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig, init_state, make_train_step

SHAPE = ShapeConfig("tiny", "train", 64, 8)


def tiny_model(grad_accum=1):
    cfg = get_config("h2o-danube-1.8b", reduced=True)
    return build(cfg, RunConfig(param_dtype="float32",
                                compute_dtype="float32",
                                grad_accum=grad_accum))


def test_loss_decreases(tmp_path):
    m = tiny_model()
    shape = ShapeConfig("tiny", "train", 64, 16)
    tc = TrainerConfig(total_steps=60, ckpt_every=1000, log_every=1000,
                       ckpt_dir=str(tmp_path / "ck"))
    tr = Trainer(m, shape, AdamWConfig(lr=1e-2, warmup_steps=5,
                                       decay_steps=60), tc)
    tr.run()
    first = np.mean([r["loss"] for r in tr.metrics_log[:5]])
    last = np.mean([r["loss"] for r in tr.metrics_log[-5:]])
    assert last < first - 0.4, (first, last)


def test_checkpoint_restart_bit_exact(tmp_path):
    m = tiny_model()
    opt = AdamWConfig(lr=1e-3)
    # continuous run to 10
    tc1 = TrainerConfig(total_steps=10, ckpt_every=100, log_every=1000,
                        ckpt_dir=str(tmp_path / "a"), ckpt_async=False)
    t1 = Trainer(m, SHAPE, opt, tc1)
    s1, _ = t1.run()
    # interrupted run: 5 steps + ckpt, new trainer resumes to 10
    tc2 = TrainerConfig(total_steps=5, ckpt_every=5, log_every=1000,
                        ckpt_dir=str(tmp_path / "b"), ckpt_async=False)
    t2 = Trainer(m, SHAPE, opt, tc2)
    t2.run()
    tc3 = TrainerConfig(total_steps=10, ckpt_every=100, log_every=1000,
                        ckpt_dir=str(tmp_path / "b"), ckpt_async=False)
    t3 = Trainer(m, SHAPE, opt, tc3)
    s3, step3 = t3.init_or_restore()
    assert step3 == 5
    s3, _ = t3.run(s3, step3)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s3["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_detection_and_ckpt(tmp_path):
    m = tiny_model()
    slow_steps = {12, 13, 14}

    def delay(step):
        if step in slow_steps:
            import time
            time.sleep(1.0)

    tc = TrainerConfig(total_steps=16, ckpt_every=1000, log_every=1000,
                       ckpt_dir=str(tmp_path / "s"), ckpt_async=False,
                       straggler_factor=3.0, straggler_patience=3)
    tr = Trainer(m, SHAPE, AdamWConfig(), tc, delay_hook=delay)
    tr.run()
    assert tr.straggler_events >= 2
    from repro.train import checkpoint as C
    assert C.available_steps(str(tmp_path / "s"))  # emergency ckpt written


def test_grad_accum_matches_single_batch():
    m1 = tiny_model(grad_accum=1)
    m2 = tiny_model(grad_accum=4)
    opt = AdamWConfig(lr=1e-3)
    s1 = init_state(m1, jax.random.PRNGKey(0), opt)
    s2 = jax.tree.map(jnp.copy, s1)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0,
                                          m1.cfg.vocab)}
    _, st1 = make_train_step(m1, opt)
    _, st2 = make_train_step(m2, opt)
    n1, met1 = st1(s1, batch)
    n2, met2 = st2(s2, batch)
    # microbatching changes averaging order; losses must agree closely
    assert abs(float(met1["loss"]) - float(met2["loss"])) < 0.05
    d = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(n1["params"]), jax.tree.leaves(n2["params"])))
    assert d < 5e-2
