"""Training loop: loss goes down, checkpoint/restart is bit-exact (clean and
fault-aware), straggler mitigation triggers on a bounded window, async
checkpoint writers never interleave, gradient accumulation is consistent."""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import RunConfig, ShapeConfig
from repro.models import build
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig, init_state, make_train_step

SHAPE = ShapeConfig("tiny", "train", 64, 8)


def tiny_model(grad_accum=1):
    cfg = get_config("h2o-danube-1.8b", reduced=True)
    return build(cfg, RunConfig(param_dtype="float32",
                                compute_dtype="float32",
                                grad_accum=grad_accum))


def test_loss_decreases(tmp_path):
    m = tiny_model()
    shape = ShapeConfig("tiny", "train", 64, 16)
    tc = TrainerConfig(total_steps=60, ckpt_every=1000, log_every=1000,
                       ckpt_dir=str(tmp_path / "ck"))
    tr = Trainer(m, shape, AdamWConfig(lr=1e-2, warmup_steps=5,
                                       decay_steps=60), tc)
    tr.run()
    first = np.mean([r["loss"] for r in tr.metrics_log[:5]])
    last = np.mean([r["loss"] for r in tr.metrics_log[-5:]])
    assert last < first - 0.4, (first, last)


def test_checkpoint_restart_bit_exact(tmp_path):
    m = tiny_model()
    opt = AdamWConfig(lr=1e-3)
    # continuous run to 10
    tc1 = TrainerConfig(total_steps=10, ckpt_every=100, log_every=1000,
                        ckpt_dir=str(tmp_path / "a"), ckpt_async=False)
    t1 = Trainer(m, SHAPE, opt, tc1)
    s1, _ = t1.run()
    # interrupted run: 5 steps + ckpt, new trainer resumes to 10
    tc2 = TrainerConfig(total_steps=5, ckpt_every=5, log_every=1000,
                        ckpt_dir=str(tmp_path / "b"), ckpt_async=False)
    t2 = Trainer(m, SHAPE, opt, tc2)
    t2.run()
    tc3 = TrainerConfig(total_steps=10, ckpt_every=100, log_every=1000,
                        ckpt_dir=str(tmp_path / "b"), ckpt_async=False)
    t3 = Trainer(m, SHAPE, opt, tc3)
    s3, step3 = t3.init_or_restore()
    assert step3 == 5
    s3, _ = t3.run(s3, step3)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s3["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_detection_and_ckpt(tmp_path):
    m = tiny_model()
    slow_steps = {12, 13, 14}

    def delay(step):
        if step in slow_steps:
            import time
            time.sleep(1.0)

    tc = TrainerConfig(total_steps=16, ckpt_every=1000, log_every=1000,
                       ckpt_dir=str(tmp_path / "s"), ckpt_async=False,
                       straggler_factor=3.0, straggler_patience=3)
    tr = Trainer(m, SHAPE, AdamWConfig(), tc, delay_hook=delay)
    tr.run()
    assert tr.straggler_events >= 2
    from repro.train import checkpoint as C
    assert C.available_steps(str(tmp_path / "s"))  # emergency ckpt written


def test_running_median_tracks_sliding_window():
    """_RunningMedian == upper median of the trailing window, at any point."""
    from repro.train.trainer import _RunningMedian
    xs = list(np.random.default_rng(0).uniform(0.01, 2.0, size=300))
    m = _RunningMedian(16)
    for i, x in enumerate(xs):
        m.add(x)
        window = xs[max(0, i - 15):i + 1]
        assert len(m) == len(window)
        assert m.median == sorted(window)[len(window) // 2]


def test_compile_step_excluded_from_straggler_window(tmp_path):
    """The first step of a run pays XLA compilation; it must neither count
    as a straggler nor contaminate the step-time median."""
    m = tiny_model()

    def delay(step):
        if step == 0:
            time.sleep(1.0)   # exaggerate the compile step

    tc = TrainerConfig(total_steps=10, ckpt_every=1000, log_every=1000,
                       ckpt_dir=str(tmp_path / "w"), ckpt_async=False,
                       straggler_factor=3.0, straggler_window=8)
    tr = Trainer(m, SHAPE, AdamWConfig(), tc, delay_hook=delay)
    tr.run()
    assert tr.straggler_events == 0
    assert not tr.metrics_log[0]["straggler"]


def test_async_ckpt_writers_never_interleave(tmp_path):
    """ckpt_every=1 with slow async writes: the join-before-save ordering
    must keep at most one writer in flight at any moment."""
    from repro.train import checkpoint as C
    import threading

    live = {"cur": 0, "max": 0}
    lock = threading.Lock()
    orig = C.np.savez

    def slow_savez(*a, **kw):
        with lock:
            live["cur"] += 1
            live["max"] = max(live["max"], live["cur"])
        time.sleep(0.05)
        try:
            return orig(*a, **kw)
        finally:
            with lock:
                live["cur"] -= 1

    m = tiny_model()
    tc = TrainerConfig(total_steps=6, ckpt_every=1, log_every=1000,
                       ckpt_dir=str(tmp_path / "q"), ckpt_async=True)
    tr = Trainer(m, SHAPE, AdamWConfig(), tc)
    with pytest.MonkeyPatch.context() as mp:
        mp.setattr(C.np, "savez", slow_savez)
        tr.run()
    assert live["max"] == 1, live
    assert C.available_steps(str(tmp_path / "q"))[-1] == 6


# ------------------------------------------------------------------ FAT ---
FAT_KW = dict(fat_policy="cl", fat_ber=1e-3, fat_ramp=6, fat_seed=17)
FAT_SHAPE = ShapeConfig("tiny", "train", 32, 4)


def fat_tiny_model():
    # 1 layer: the FT stack traces every linear site, so compile cost scales
    # with depth — one block keeps three trainer builds tier-1-sized
    cfg = dataclasses.replace(get_config("h2o-danube-1.8b", reduced=True),
                              n_layers=1)
    return build(cfg, RunConfig(param_dtype="float32",
                                compute_dtype="float32"))


def test_fat_resume_determinism(tmp_path):
    """Interrupt-at-k + resume == uninterrupted, bit for bit, *with faults
    on*: params AND per-step (loss, fat_ber) metrics.  This pins the whole
    key-stream contract — the resumed run folds its fault keys from the
    restored step counter (never replaying step 0's draws), the BER ramp is
    a function of the same counter, and the data iterator restores its
    position from the checkpoint's data_state."""
    m = fat_tiny_model()
    opt = AdamWConfig(lr=1e-3)
    tc1 = TrainerConfig(total_steps=8, ckpt_every=100, log_every=1000,
                        ckpt_dir=str(tmp_path / "a"), ckpt_async=False,
                        **FAT_KW)
    t1 = Trainer(m, FAT_SHAPE, opt, tc1)
    s1, _ = t1.run()
    # the ramp actually ramps: monotone, hits the target, and is logged
    bers = [r["fat_ber"] for r in t1.metrics_log]
    assert bers == sorted(bers)
    assert bers[0] == 0.0 and bers[-1] == pytest.approx(1e-3)

    tc2 = TrainerConfig(total_steps=4, ckpt_every=4, log_every=1000,
                        ckpt_dir=str(tmp_path / "b"), ckpt_async=False,
                        **FAT_KW)
    t2 = Trainer(m, FAT_SHAPE, opt, tc2)
    t2.run()
    tc3 = TrainerConfig(total_steps=8, ckpt_every=100, log_every=1000,
                        ckpt_dir=str(tmp_path / "b"), ckpt_async=False,
                        **FAT_KW)
    t3 = Trainer(m, FAT_SHAPE, opt, tc3)
    s3, step3 = t3.init_or_restore()
    assert step3 == 4
    s3, _ = t3.run(s3, step3)

    for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    cont = {r["step"]: r for r in t1.metrics_log}
    for r in t3.metrics_log:
        assert r["loss"] == cont[r["step"]]["loss"], r["step"]
        assert r["fat_ber"] == cont[r["step"]]["fat_ber"], r["step"]
    # the resumed step is step 5's coordinate, not a replay of step 1
    assert t3.metrics_log[0]["step"] == 5
    assert t3.metrics_log[0]["loss"] != t1.metrics_log[0]["loss"]


def test_grad_accum_matches_single_batch():
    m1 = tiny_model(grad_accum=1)
    m2 = tiny_model(grad_accum=4)
    opt = AdamWConfig(lr=1e-3)
    s1 = init_state(m1, jax.random.PRNGKey(0), opt)
    s2 = jax.tree.map(jnp.copy, s1)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0,
                                          m1.cfg.vocab)}
    _, st1 = make_train_step(m1, opt)
    _, st2 = make_train_step(m2, opt)
    n1, met1 = st1(s1, batch)
    n2, met2 = st2(s2, batch)
    # microbatching changes averaging order; losses must agree closely
    assert abs(float(met1["loss"]) - float(met2["loss"])) < 0.05
    d = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(n1["params"]), jax.tree.leaves(n2["params"])))
    assert d < 5e-2
