"""Per-architecture reduced smoke tests: one forward/train step on CPU with
output shape + finiteness assertions, plus prefill->decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build


def make_batch(cfg, B, S, key):
    ks = jax.random.split(key, 3)
    n_text = S - (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    batch = {"tokens": jax.random.randint(ks[0], (B, n_text), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (B, cfg.n_frontend_tokens, cfg.d_model))
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(ks[2], (B, S, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_loss_and_grads(arch):
    cfg = get_config(arch, reduced=True)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 32, jax.random.PRNGKey(1))
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(m.loss, has_aux=True))(params, batch)
    assert np.isfinite(float(loss))
    assert abs(float(loss) - float(jnp.log(cfg.vocab))) < 1.5
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_matches_full_forward(arch):
    cfg = get_config(arch, reduced=True)
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 33
    batch_full = make_batch(cfg, B, S, jax.random.PRNGKey(1))
    batch_pre = dict(batch_full)
    batch_pre["tokens"] = batch_full["tokens"][:, :-1]
    _, logits_full = jax.jit(lambda p, b: m.prefill(p, b))(params, batch_full)
    caches, _ = jax.jit(lambda p, b: m.prefill(p, b, max_len=S + 4))(
        params, batch_pre)
    _, logits_dec = jax.jit(lambda p, c, t, i: m.decode_step(p, c, t, i))(
        params, caches, batch_full["tokens"][:, -1],
        jnp.asarray(S - 1, jnp.int32))
    scale = float(jnp.abs(logits_full).max()) + 1e-9
    err = float(jnp.abs(logits_full - logits_dec).max()) / scale
    assert err < 0.02, err


@pytest.mark.parametrize("arch", ARCHS)
def test_scan_path_matches_unrolled(arch):
    """Stacked-scan layers and python-loop layers are the same model."""
    cfg_u = get_config(arch, reduced=True)
    cfg_s = dataclasses.replace(cfg_u, unroll=False)
    mu, ms = build(cfg_u), build(cfg_s)
    pu = mu.init(jax.random.PRNGKey(0))
    ps = ms.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg_u, 2, 16, jax.random.PRNGKey(1))
    lu, _ = jax.jit(lambda p, b: mu.loss(p, b))(pu, batch)
    ls, _ = jax.jit(lambda p, b: ms.loss(p, b))(ps, batch)
    # different init trees (per-layer fold_in vs vmap split) — only check
    # both are healthy; exact equivalence is covered by decode tests
    assert np.isfinite(float(lu)) and np.isfinite(float(ls))


EXPECTED_PARAMS = {  # published sizes (paligemma/seamless = backbone only)
    "gemma2-27b": 27.2e9, "glm4-9b": 9.4e9, "qwen2-7b": 7.6e9,
    "h2o-danube-1.8b": 1.8e9, "dbrx-132b": 132e9,
    "qwen3-moe-235b-a22b": 235e9, "paligemma-3b": 2.5e9,
    "seamless-m4t-medium": 0.7e9, "mamba2-2.7b": 2.7e9,
    "recurrentgemma-9b": 8.6e9,
}


def test_full_configs_construct_specs_only():
    """FULL configs are exercised via ShapeDtypeStructs only (no alloc) and
    land within 35% of the published parameter counts."""
    from repro.configs import SHAPES
    for arch in ARCHS:
        cfg = get_config(arch)
        m = build(cfg)
        spec = m.param_specs()
        n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(spec))
        exp = EXPECTED_PARAMS[arch]
        assert 0.65 * exp < n_params < 1.35 * exp, (arch, n_params, exp)
        bs = m.batch_specs(SHAPES["train_4k"])
        assert bs["tokens"].shape[0] == 256
