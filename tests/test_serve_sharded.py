"""Sharded fault-tolerant serving: the per-shard determinism battery.

Each test runs in a subprocess with ``--xla_force_host_platform_device_count``
(the main process keeps its single-device view) and proves one clause of the
partition-exactness contract from docs/serving.md §Sharded serving:

  * temp-0 tokens from ``Engine`` and ``Scheduler`` are **bit-identical**
    between the no-mesh path and an 8-way (4 dp x 2 tp) mesh, for a dense
    (SWA) and a MoE config, under crt3 and under per-row weight faults —
    partitionable threefry (switched on by ``repro.core.faults``) makes every
    fault draw partition-invariant, and the integer FT datapath accumulates
    exactly under partitioned psum;
  * the scheduler's alone-vs-crowded per-request invariance survives TP
    sharding;
  * ``fold_axis_index`` gives shard_map regions per-shard streams that a
    host-side loop reproduces via ``fold_stream(key, s)``;
  * on a real mesh, paged pools are never DP-sharded on the pool dim.

The mesh is (4, 2) deliberately: tp=2 divides the reduced configs' kv heads
(2), heads (4) and experts (4), so caches head-shard (no split-K partial
softmax, which is *not* bitwise partition-invariant) and the MoE combine is
a two-term psum.  MoE capacity_factor is raised to 8.0 because capacity is
computed from per-shard token counts — with drop headroom the routed sets
match exactly (same convention as test_multidevice.py).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    # --xla_allow_excess_precision=false: XLA's default elides explicit
    # f32->bf16->f32 rounding when a fusion keeps the wider type, and the
    # elision decision differs between partitioned and unpartitioned graphs
    # — the one non-bitwise-invariant op in the whole serving path (see
    # docs/serving.md "Sharded serving").
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        "--xla_allow_excess_precision=false")
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


_SETUP = """
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro import ft
    from repro.configs import get_config
    from repro.models import build

    def load(name):
        cfg = get_config(name, reduced=True)
        if cfg.moe is not None:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        m = build(cfg)
        return cfg, m, m.init(jax.random.PRNGKey(0))

    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
"""


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "qwen3-moe-235b-a22b"])
def test_engine_sharded_bit_identical(arch):
    out = run_py(_SETUP + f"""
    from repro.serve.engine import Engine, ServeConfig
    cfg, m, params = load({arch!r})
    batch = {{'tokens': jax.random.randint(jax.random.PRNGKey(1), (4, 8),
                                           0, cfg.vocab)}}
    scfg = ServeConfig(max_new_tokens=6)
    for policy in ('crt3',
                   ft.get_policy('crt1', ber=3e-3, weight_faults=True)):
        ref = Engine(m, params, cfg=scfg, policy=policy).generate(
            batch, seed=3)
        shd = Engine(m, params, mesh=mesh, cfg=scfg, policy=policy).generate(
            batch, seed=3)
        assert (np.asarray(ref) == np.asarray(shd)).all(), (
            np.asarray(ref).tolist(), np.asarray(shd).tolist())
    print('OK')
    """)
    assert "OK" in out


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "qwen3-moe-235b-a22b"])
def test_scheduler_sharded_bit_identical(arch):
    out = run_py(_SETUP + f"""
    from repro.serve.scheduler import Request, Scheduler, SchedulerConfig
    cfg, m, params = load({arch!r})
    def prompt(n, seed):
        return [int(t) for t in jax.random.randint(
            jax.random.PRNGKey(seed), (n,), 0, cfg.vocab)]
    mk = lambda: [Request(rid=i, tokens=prompt(4 + (i % 3), 20 + i),
                          max_new_tokens=5) for i in range(6)]
    scfg = SchedulerConfig(max_batch=4, buckets=(8,), max_new_tokens=6,
                           decode_chunk=3)
    for policy in ('crt3',
                   ft.get_policy('crt1', ber=3e-3, weight_faults=True)):
        ref = Scheduler(m, params, scfg, policy=policy).run(mk())
        shd = Scheduler(m, params, scfg, policy=policy, mesh=mesh).run(mk())
        for i in range(6):
            assert ref[i].generated == shd[i].generated, (
                i, ref[i].generated, shd[i].generated)
    print('OK')
    """)
    assert "OK" in out


@pytest.mark.slow
def test_scheduler_alone_vs_crowded_under_tp():
    """Per-request fault accounting survives sharding: a request's tokens
    under an 8-way mesh are a pure function of (rid, its own prompt)."""
    out = run_py(_SETUP + """
    from repro.serve.scheduler import Request, Scheduler, SchedulerConfig
    cfg, m, params = load('h2o-danube-1.8b')
    def prompt(n, seed):
        return [int(t) for t in jax.random.randint(
            jax.random.PRNGKey(seed), (n,), 0, cfg.vocab)]
    policy = ft.get_policy('crt1', ber=3e-3, weight_faults=True)
    scfg = SchedulerConfig(max_batch=4, buckets=(8,), max_new_tokens=6,
                           decode_chunk=3)
    alone = Scheduler(m, params, scfg, policy=policy, mesh=mesh).run(
        [Request(rid=7, tokens=prompt(5, 7), max_new_tokens=6)])
    crowd = [Request(rid=7, tokens=prompt(5, 7), max_new_tokens=6),
             Request(rid=8, tokens=prompt(3, 8), max_new_tokens=6),
             Request(rid=9, tokens=prompt(7, 9), max_new_tokens=6)]
    crowded = Scheduler(m, params, scfg, policy=policy, mesh=mesh).run(crowd)
    assert alone[7].generated == crowded[7].generated
    print('OK')
    """)
    assert "OK" in out


@pytest.mark.slow
def test_fold_axis_index_shard_map_contract():
    """Shard s's stream inside shard_map == fold_stream(key, s) on the host:
    the per-shard key-stream contract for explicitly-partitioned regions."""
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core.faults import fold_axis_index, fold_stream
    from repro.parallel.compat import shard_map

    mesh = Mesh(np.array(jax.devices()), ('i',))
    base = jax.random.PRNGKey(42)

    def f(_):
        k = fold_axis_index(base, 'i')
        return jax.random.uniform(k, (1, 4))

    y = shard_map(f, mesh=mesh, in_specs=(P('i'),), out_specs=P('i'),
                  check=False)(jnp.zeros((8,)))
    ref = np.stack([np.asarray(jax.random.uniform(fold_stream(base, s), (4,)))
                    for s in range(8)])
    assert (np.asarray(y) == ref).all()
    print('OK')
    """)
    assert "OK" in out


@pytest.mark.slow
def test_paged_pool_replicated_on_real_mesh():
    """The satellite-1 regression on real devices: paged pool leaves are
    fully addressable from every DP shard (pool dim replicated), while dense
    per-slot rows shard over the batch."""
    out = run_py(_SETUP + """
    from repro.parallel import sharding as S
    cfg, m, params = load('h2o-danube-1.8b')
    caches = m.init_cache(4, 16, paged=(8, 17))
    sh = S.cache_shardings(caches, mesh)

    def leaves_with_paths(tree):
        return jax.tree_util.tree_flatten_with_path(tree)[0]

    def axes(entry):
        if entry is None:
            return set()
        return set(entry) if isinstance(entry, tuple) else {entry}

    pool_seen = bt_seen = 0
    for path, s in leaves_with_paths(sh):
        names = [str(getattr(k, 'key', '')) for k in path]
        off = 1 if names[0].startswith('seg') else 0   # scan-stack prefix
        spec = list(s.spec) + [None] * 8
        if names[-1] in ('k', 'v'):
            # pool + block dims replicated: addressable from every shard
            assert spec[off] is None and spec[off + 1] is None, (names,
                                                                 s.spec)
            pool_seen += 1
        if names[-1] == 'bt':
            assert 'data' in axes(spec[off]), (names, s.spec)
            bt_seen += 1
    assert pool_seen and bt_seen
    dense = S.cache_shardings(m.init_cache(4, 16), mesh)
    for path, s in leaves_with_paths(dense):
        off = 1 if str(getattr(path[0], 'key', '')).startswith('seg') else 0
        assert 'data' in axes((list(s.spec) + [None] * 8)[off]), (path,
                                                                  s.spec)
    print('OK')
    """)
    assert "OK" in out
