"""Fault-aware training (FAT): straight-through gradients on the bit-exact
faulty datapath, the BER ramp schedule, the training efficacy claim (a
FAT-trained net holds more accuracy under deployment faults at matched clean
accuracy), and the ``fat_ber`` DSE axis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bayesopt as B
from repro.core import perfmodel as P
from repro.core.evaluate import trained_cnn, trained_cnn_fat
from repro.core.pipeline import _policy_from_cfg, optimize
from repro.core.strategies import make_strategies
from repro.ft import get_policy, protect_linear, protect_linear_ste
from repro.train.train_step import fat_ber_at

FAT_BER = 1.5e-3
FAT_RAMP = 50       # BER warm-up steps; full fault pressure for the rest
STRESS_BER = 5e-3   # deployment stress, well past the training exposure
STEPS = 200   # shares the lru cache with tests/test_cnn_crosslayer.py


# ------------------------------------------------------------------ STE ---
def test_ste_forward_bit_exact():
    """The FAT forward IS the deployment forward: protect_linear_ste must
    reproduce protect_linear bit for bit (integer datapath, same key)."""
    root = jax.random.PRNGKey(3)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    w = jax.random.normal(jax.random.PRNGKey(2), (8, 5))
    for i, pol in enumerate((get_policy("cl", ber=2e-3),
                             get_policy("base", ber=5e-3),
                             get_policy("arch", ber=1e-3))):
        k = jax.random.fold_in(root, i)
        y_ref = protect_linear(k, x, w, pol)
        # ftlint: disable=FTL001 -- paired run: identical fault stream
        y_ste = protect_linear_ste(k, x, w, pol)
        np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_ste))


def test_ste_backward_is_clean_matmul():
    """Gradients pass straight through the fault/protect/quantize stack as if
    the layer were the clean float matmul."""
    k = jax.random.PRNGKey(3)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    w = jax.random.normal(jax.random.PRNGKey(2), (8, 5))
    pol = get_policy("cl", ber=2e-3)

    def f(x, w):
        return (protect_linear_ste(k, x, w, pol) ** 2).sum()

    def f_clean(x, w):
        y = protect_linear(k, jax.lax.stop_gradient(x),
                           jax.lax.stop_gradient(w), pol)
        return (y ** 2).sum()

    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    # cotangent of sum(y^2) is 2y with y the *faulty* output; the STE rule
    # then maps it through the clean matmul's transpose
    y = protect_linear(k, x, w, pol)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(2 * y @ w.T),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(2 * x.T @ y),
                               rtol=1e-5)
    # and the all-stop-gradient version really is gradient-dead
    gx0, gw0 = jax.grad(f_clean, argnums=(0, 1))(x, w)
    assert float(jnp.abs(gx0).max()) == 0.0
    assert float(jnp.abs(gw0).max()) == 0.0


def test_ste_grads_flow_under_jit_and_vmap():
    k = jax.random.PRNGKey(0)
    pol = get_policy("cl", ber=1e-3)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 8))
    w = jax.random.normal(jax.random.PRNGKey(2), (8, 5))

    @jax.jit
    def g(x, w):
        f = lambda xi: protect_linear_ste(k, xi, w, pol).sum()
        return jax.grad(lambda w_: jax.vmap(f)(x).sum() * 0 +
                        protect_linear_ste(k, x[0], w_, pol).sum())(w)
    assert float(jnp.abs(g(x, w)).max()) > 0


# ----------------------------------------------------------- BER ramp ---
def test_fat_ber_ramp():
    bers = [float(fat_ber_at(2e-3, 10, s)) for s in range(15)]
    np.testing.assert_allclose(bers[:11],
                               [2e-3 * i / 10 for i in range(11)], rtol=1e-6)
    assert bers[11:] == pytest.approx([2e-3] * 4)   # clamps at the target
    assert float(fat_ber_at(2e-3, 0, 5)) == pytest.approx(2e-3)  # no ramp
    # traced step (the in-jit counter) works too
    tr = jax.jit(lambda s: fat_ber_at(2e-3, 10, s))(jnp.int32(5))
    assert abs(float(tr) - 1e-3) < 1e-9


# ------------------------------------------------------- FAT efficacy ---
def test_fat_beats_baseline_under_fault():
    """The paper-level claim, at tier-1 scale: train the benchmark CNN
    through the injected-fault datapath and it holds more accuracy under
    deployment-time faults than the clean-trained twin — at matched clean
    accuracy.  Margins are calibrated against the deterministic oracle
    (fixed data/fault seeds, partitionable-threefry streams): measured
    clean gap 0.006, measured fault margins +0.067 (unprotected) and
    +0.118 (cross-layer) at the stress BER; asserted with slack."""
    base = trained_cnn("vgg", STEPS)
    fat = trained_cnn_fat("vgg", STEPS, FAT_BER, fat_ramp=FAT_RAMP)
    # matched clean accuracy: FAT must not cost the clean operating point
    assert fat.clean_acc > base.clean_acc - 0.01, \
        (base.clean_acc, fat.clean_acc)
    # accuracy under stress faults, both on the raw unprotected datapath
    # and under the deployment cross-layer stack
    for name in ("base", "cl"):
        pol = get_policy(name, ber=STRESS_BER)
        a_base = base.accuracy(pol)
        a_fat = fat.accuracy(pol)
        assert a_fat > a_base + 0.03, (name, a_base, a_fat)


def test_fat_shrinks_required_protection():
    """FAT substitutes for protection hardware: at the stress BER there is
    an accuracy target the clean-trained net only reaches by escalating from
    the cross-layer stack to whole-array spatial TMR (~2x execution time),
    while the FAT-trained net reaches it on the cross-layer stack.
    Target 0.75 sits between the deterministic measured points:
    base@cl 0.689 < 0.75 <= fat@cl 0.807 <= base@arch 0.928."""
    base = trained_cnn("vgg", STEPS)
    fat = trained_cnn_fat("vgg", STEPS, FAT_BER, fat_ramp=FAT_RAMP)
    stress = STRESS_BER
    target = 0.75
    cl = get_policy("cl", ber=stress)
    arch = get_policy("arch", ber=stress)
    assert base.accuracy(cl) < target        # cl alone fails the baseline
    assert base.accuracy(arch) >= target     # ...it must escalate to TMR
    assert fat.accuracy(cl) >= target, \
        (fat.accuracy(cl), target)           # FAT makes cl sufficient
    # and the escalation FAT avoids is the expensive one: whole-array TMR
    # roughly doubles execution time where the cross-layer stack is ~free
    strats = make_strategies()
    layers = P.lm_layer_gemms(2, 128, 512, 4, 32, 4, seq=64)
    assert (strats["arch"].perf_loss(layers)
            > strats["cl"].perf_loss(layers) + 0.5)


# ------------------------------------------------------- fat_ber axis ---
def test_fat_table1_space():
    space = B.fat_table1_space((0.0, 1e-3))
    names = [p.name for p in space]
    assert names[:-1] == [p.name for p in B.table1_space()]
    assert names[-1] == "fat_ber"
    assert space[-1].values == (0.0, 1e-3)


def test_policy_from_cfg_strips_train_axes():
    pol = _policy_from_cfg({"s_th": 0.1, "fat_ber": 2e-3}, 1e-3)
    assert pol.algorithm.s_th == 0.1
    assert not hasattr(pol, "fat_ber")   # training axis never enters policy


def _dse_space():
    return [
        B.Param("s_th", (0.05, 0.1, 0.2), monotone=+1),
        B.Param("ib_th", (2, 3), monotone=+1),
        B.Param("nb_th", (1, 2), monotone=+1),
        B.Param("fat_ber", (0.0, FAT_BER), monotone=0),
    ]


def test_fat_axis_routes_to_oracle_and_selects_fat():
    """Synthetic oracle where training-time hardening is the only way to be
    feasible at low protection: the DSE must (a) thread cfg['fat_ber'] to the
    oracle, (b) keep it off the ProtectionPolicy, (c) select a fat point."""
    layers = P.lm_layer_gemms(2, 128, 512, 4, 32, 4, seq=64)
    seen = []

    def acc(pol, fat_ber=0.0):
        seen.append(fat_ber)
        prot = pol.algorithm.s_th * 0.3
        return 0.70 + (0.12 if fat_ber > 0 else 0.0) + prot

    cons = B.Constraints(acc_min=0.80, perf_max=2.0, bw_max=2.0)
    res = optimize(acc, layers, cons, ber=FAT_BER, iter_max_step=24, seed=3,
                   space=_dse_space())
    assert any(fb > 0 for fb in seen)
    assert res.policy is not None
    assert res.dse.best["fat_ber"] == FAT_BER   # fat is the cheap feasibility
    assert not hasattr(res.policy, "fat_ber")


def test_fat_axis_batched_matches_sequential_feasibility():
    layers = P.lm_layer_gemms(2, 128, 512, 4, 32, 4, seq=64)

    def acc(pol, fat_ber=0.0):
        return 0.70 + (0.12 if fat_ber > 0 else 0.0) + pol.algorithm.s_th * 0.3

    calls = {"batched": 0}

    def acc_batch(pols, fat_bers=None):
        fat_bers = fat_bers or [0.0] * len(pols)
        calls["batched"] += len(pols)
        return [acc(p, fb) for p, fb in zip(pols, fat_bers)]

    cons = B.Constraints(acc_min=0.80, perf_max=2.0, bw_max=2.0)
    seq = optimize(acc, layers, cons, ber=FAT_BER, iter_max_step=24, seed=3,
                   space=_dse_space())
    bat = optimize(acc, layers, cons, ber=FAT_BER, iter_max_step=24, seed=3,
                   space=_dse_space(), batch_size=4, acc_oracle_batch=acc_batch)
    assert calls["batched"] > 0
    assert (seq.policy is None) == (bat.policy is None)
    if bat.policy is not None:
        assert bat.dse.best["fat_ber"] == FAT_BER
