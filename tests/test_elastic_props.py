"""Property-based invariants of the elastic rescale plan (hypothesis).

The deterministic grid lives in tests/test_elastic.py; these properties pin
the contract for *all* (old_dp, survivors, model_axis) combinations:

  * validity:        1 <= new_dp <= old_dp and new_dp fits the survivors
  * divisibility:    old_dp % new_dp == 0
  * batch preserved: new_dp * grad_accum_scale == old_dp
  * idempotence:     a plan applied to its own outcome changes nothing
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.train.elastic import plan_rescale  # noqa: E402


class _MeshLike:
    def __init__(self, dp):
        self.shape = {"data": dp, "model": 1}


@settings(max_examples=200, deadline=None)
@given(old_dp=st.integers(1, 64), lost=st.integers(0, 63),
       model_axis=st.integers(1, 8))
def test_plan_invariants(old_dp, lost, model_axis):
    total = old_dp * model_axis
    surviving = max(total - lost, 1)
    plan = plan_rescale(_MeshLike(old_dp), surviving, model_axis)
    assert 1 <= plan.new_dp <= old_dp
    assert old_dp % plan.new_dp == 0
    assert plan.new_dp * plan.grad_accum_scale == old_dp
    if surviving >= model_axis:
        assert plan.new_dp * model_axis <= max(surviving, model_axis)


@settings(max_examples=100, deadline=None)
@given(old_dp=st.integers(1, 64), lost=st.integers(0, 63),
       model_axis=st.integers(1, 8))
def test_plan_idempotent(old_dp, lost, model_axis):
    """Re-planning from the post-rescale world with no further loss is the
    identity: the closed loop converges in one application."""
    total = old_dp * model_axis
    surviving = max(total - lost, 1)
    plan = plan_rescale(_MeshLike(old_dp), surviving, model_axis)
    again = plan_rescale(_MeshLike(plan.new_dp),
                         plan.new_dp * model_axis, model_axis)
    assert again.new_dp == plan.new_dp
    assert again.grad_accum_scale == 1
    assert not again.changed
