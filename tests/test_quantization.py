import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import quantization as Q


def test_quantize_roundtrip_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    q, s = Q.quantize(x)
    err = jnp.abs(Q.dequantize(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_saturate_clips_to_24bit():
    acc = jnp.array([1 << 25, -(1 << 25), 100])
    out = Q.saturate(acc)
    assert int(out[0]) == (1 << 23) - 1
    assert int(out[1]) == -(1 << 23)
    assert int(out[2]) == 100


def test_trunc_lsb_respects_q_scale():
    for q_scale in (0, 3, 7, 12):
        t = Q.choose_trunc_lsb(jnp.asarray(1000.0), q_scale=q_scale)
        assert int(t) >= q_scale
        assert int(t) <= Q.ACC_BITS - Q.OUT_BITS


def test_truncate_acc_window():
    acc = jnp.asarray([0b101100100])  # 356
    out = Q.truncate_acc(acc, 2)
    assert int(out[0]) == (356 + 2) >> 2


def test_fake_quant_linear_accuracy():
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 48))
    w = jax.random.normal(jax.random.PRNGKey(1), (48, 16))
    y, aux = Q.fake_quant_linear(x, w)
    ref = x @ w
    rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
    assert rel < 0.05
    assert int(aux["t"]) >= 0


@settings(max_examples=20, deadline=None)
@given(q_scale=st.integers(0, 12), seed=st.integers(0, 2 ** 16))
def test_qmatmul_monotone_quant_error(q_scale, seed):
    """Constrained quantization never produces invalid windows and the
    paper's premise holds: small Q_scale keeps error negligible."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (16, 32))
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (32, 8))
    xq, _ = Q.quantize(x)
    wq, _ = Q.quantize(w)
    yq, t = Q.qmatmul(xq, wq, q_scale=q_scale)
    assert int(t) >= q_scale
    assert int(jnp.abs(yq).max()) <= 127


def test_quant_error_grows_with_extreme_q_scale():
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 32))
    lo = float(Q.quant_error(x, 0))
    hi = float(Q.quant_error(x, 14))
    assert hi >= lo  # Fig. 11: accuracy degrades only at large Q_scale
