"""Multi-device behaviour (sharding rules, elastic re-mesh, distributed MoE)
run in subprocesses with forced host-device counts, so the main test process
keeps its single-device view."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import RunConfig
        from repro.models import build
        from repro.optim import AdamWConfig
        from repro.train import init_state, make_train_step
        import dataclasses
        cfg = dataclasses.replace(get_config('h2o-danube-1.8b', reduced=True),
                                  unroll=False)
        m = build(cfg, RunConfig(param_dtype='float32', compute_dtype='float32'))
        opt = AdamWConfig(lr=1e-3)
        batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (8, 64),
                                              0, cfg.vocab)}
        s0 = init_state(m, jax.random.PRNGKey(0), opt)
        _, st_local = make_train_step(m, opt, mesh=None)
        s1, met1 = st_local(jax.tree.map(jnp.copy, s0), batch)
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        _, st_mesh = make_train_step(m, opt, mesh=mesh)
        s2, met2 = st_mesh(jax.tree.map(jnp.copy, s0), batch)
        d = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(s1['params']), jax.tree.leaves(s2['params'])))
        print('LOSSDIFF', abs(float(met1['loss']) - float(met2['loss'])))
        print('PARAMDIFF', d)
        assert abs(float(met1['loss']) - float(met2['loss'])) < 1e-3
        assert d < 1e-3
        print('OK')
    """)
    assert "OK" in out


@pytest.mark.slow
def test_moe_shard_map_matches_single_device():
    out = run_py("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config
        from repro.configs.base import RunConfig
        from repro.models import build
        from repro.parallel import sharding as S
        from repro.parallel.ctx import mesh_ctx
        cfg = dataclasses.replace(get_config('qwen3-moe-235b-a22b', reduced=True),
                                  unroll=False)
        # capacity is per-shard, so drop sets differ between partitionings;
        # with headroom for every assignment the paths must agree exactly
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        m = build(cfg, RunConfig(param_dtype='float32', compute_dtype='float32'))
        params = m.init(jax.random.PRNGKey(0))
        batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (8, 16),
                                              0, cfg.vocab)}
        l0, _ = jax.jit(m.loss)(params, batch)     # single-device path
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        ctx = S.make_ctx(mesh)
        def loss_mesh(p, b):
            with mesh_ctx(ctx):
                return m.loss(p, b)
        l1, _ = jax.jit(loss_mesh)(params, batch)  # shard_map EP path
        print('L0', float(l0), 'L1', float(l1))
        assert abs(float(l0) - float(l1)) < 2e-3
        print('OK')
    """)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_remesh_restore(tmp_path):
    out = run_py(f"""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config
        from repro.configs.base import RunConfig, ShapeConfig
        from repro.models import build
        from repro.optim import AdamWConfig
        from repro.train import init_state, make_train_step, state_shardings
        from repro.train import checkpoint as C
        from repro.train.elastic import plan_rescale, remesh_restore
        cfg = dataclasses.replace(get_config('h2o-danube-1.8b', reduced=True),
                                  unroll=False)
        m = build(cfg, RunConfig(param_dtype='float32', compute_dtype='float32'))
        opt = AdamWConfig(lr=1e-3)
        batch = {{'tokens': jax.random.randint(jax.random.PRNGKey(1), (8, 64),
                                               0, cfg.vocab)}}
        mesh8 = jax.make_mesh((4, 2), ('data', 'model'))
        _, step8 = make_train_step(m, opt, mesh=mesh8)
        s = init_state(m, jax.random.PRNGKey(0), opt)
        s, _ = step8(s, batch)
        C.save('{tmp_path}/ck', s, 1)
        # "lose" half the data hosts: 8 -> 4 devices
        plan = plan_rescale(mesh8, surviving_devices=4, model_axis=2)
        assert plan.new_dp == 2 and plan.grad_accum_scale == 2
        mesh4 = jax.make_mesh((2, 2), ('data', 'model'))
        like = jax.eval_shape(lambda k: init_state(m, k, opt),
                              jax.random.PRNGKey(0))
        s4, step, _, ctx = remesh_restore('{tmp_path}/ck', like, mesh4)
        assert step == 1
        _, step4 = make_train_step(m, opt, mesh=mesh4)
        s4b, met = step4(s4, batch)
        assert np.isfinite(float(met['loss']))
        print('OK')
    """)
    assert "OK" in out


@pytest.mark.slow
def test_grad_compression_psum():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.compression import compressed_psum_test
        err = compressed_psum_test(jax.random.PRNGKey(0), n_dev=8)
        print('ERR', err)
        assert err < 0.02
        print('OK')
    """)
    assert "OK" in out
