"""Sharding-rule unit tests on the abstract production mesh (no devices)."""
import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh, PartitionSpec as P
from jax.tree_util import DictKey

from repro.parallel import sharding as S

def _abstract_mesh(sizes, names):
    try:  # jax >= 0.5: AbstractMesh(axis_sizes, axis_names)
        return AbstractMesh(sizes, names)
    except TypeError:  # jax 0.4.x: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(names, sizes)))


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH_MP = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def spec_of(names, shape, mesh=MESH):
    path = tuple(DictKey(n) for n in names)
    leaf = jax.ShapeDtypeStruct(shape, jnp.bfloat16)
    return S.param_spec(path, leaf, mesh)


def test_in_proj_2d_sharded():
    assert spec_of(("seg0", "s0", "attn", "wq"), (23, 4608, 4096)) == \
        P(None, ("data",), "model")


def test_out_proj_transposed():
    assert spec_of(("seg0", "s0", "attn", "wo"), (23, 4096, 4608)) == \
        P(None, "model", ("data",))


def test_multipod_fsdp_axes():
    s = spec_of(("seg0", "s0", "ffn", "wi"), (23, 4608, 36864), MESH_MP)
    assert s == P(None, ("pod", "data"), "model")


def test_moe_experts_over_model():
    s = spec_of(("seg0", "s0", "ffn", "wi"), (94, 128, 4096, 1536))
    assert s == P(None, "model", ("data",), None)


def test_indivisible_dims_replicated():
    # seamless vocab 256206 doesn't divide 16 => replicated on that dim
    s = spec_of(("embed",), (256206, 1024))
    assert s == P(None, ("data",))


def test_norms_replicated():
    assert spec_of(("seg0", "s0", "ln1"), (23, 4608)) == P(None, None)


def test_unstacked_tail_params():
    assert spec_of(("final_norm",), (4608,)) == P(None)


def sds(shape):
    # ShapeDtypeStructs, NOT real arrays — these are full-scale cache shapes
    return jax.ShapeDtypeStruct(shape, jnp.bfloat16)


def test_serving_layout_drops_fsdp():
    tree = {"seg0": {"s0": {"attn": {"wq": sds((2, 64, 64))}}}}
    sh = S.param_shardings(tree, MESH, no_fsdp=True)
    spec = jax.tree.leaves(sh)[0].spec
    assert spec == P(None, None, "model")


def test_cache_split_k_when_heads_indivisible():
    # glm4: kv=2 heads can't shard 16 ways => cache length sharded instead
    cache = {"seg0": {"s0": {"attn": {"k": sds((40, 128, 32768, 2, 128))}}}}
    sh = S.cache_shardings(cache, MESH)
    spec = jax.tree.leaves(sh)[0].spec
    assert spec == P(None, ("data",), "model", None, None)


def test_cache_heads_preferred_when_divisible():
    cache = {"seg0": {"s0": {"attn": {"k": sds((23, 128, 32768, 16, 128))}}}}
    sh = S.cache_shardings(cache, MESH)
    spec = jax.tree.leaves(sh)[0].spec
    assert spec == P(None, ("data",), None, "model", None)


def test_paged_pool_never_dp_sharded():
    """Regression: a paged pool leaf (n_blocks, block_size, KH, Dh) used to
    match the dense (B, C, KH, Dh) branch and get its *pool* dim DP-sharded
    as if it were batch — but block tables hold global block ids, so any
    sharding of dims 0/1 breaks paged lookup.  Pools shard on kv heads over
    'model' only; the block table itself shards with the batch."""
    pool = (4096, 16, 16, 128)     # divisible by 16 on dims 0/1/2: tempting
    cache = {"seg0": {"s0": {"attn": {
        "k": sds((23,) + pool), "v": sds((23,) + pool),
        "bt": jax.ShapeDtypeStruct((23, 256, 32), jnp.int32)}}}}
    sh = S.cache_shardings(cache, MESH)
    attn = jax.tree.leaves(sh["seg0"]["s0"]["attn"]["k"])[0].spec
    assert attn == P(None, None, None, "model", None)
    assert jax.tree.leaves(sh["seg0"]["s0"]["attn"]["v"])[0].spec == attn
    # the per-slot block table is batch-major state: batch over DP
    assert jax.tree.leaves(sh["seg0"]["s0"]["attn"]["bt"])[0].spec == \
        P(None, ("data",), None)


def test_paged_pool_heads_indivisible_stays_replicated():
    # no split-K fallback for pools: the in-block dim is block_size, not
    # cache length, so an indivisible head count leaves the pool replicated
    pool = (4096, 16, 2, 128)
    cache = {"l0": {"attn": {"k": sds(pool), "v": sds(pool),
                             "bt": jax.ShapeDtypeStruct((8, 32), jnp.int32)}}}
    sh = S.cache_shardings(cache, MESH)
    assert jax.tree.leaves(sh["l0"]["attn"]["k"])[0].spec == \
        P(None, None, None, None)
    # dense siblings (cross-attn buffers etc.) keep the dense rules
    cache["l0"]["cross"] = {"ck": sds((32, 128, 16, 128))}
    sh = S.cache_shardings(cache, MESH)
    assert jax.tree.leaves(sh["l0"]["cross"]["ck"])[0].spec == \
        P(("data",), None, "model", None)
