"""Sharding-rule unit tests on the abstract production mesh (no devices)."""
import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh, PartitionSpec as P
from jax.tree_util import DictKey

from repro.parallel import sharding as S

def _abstract_mesh(sizes, names):
    try:  # jax >= 0.5: AbstractMesh(axis_sizes, axis_names)
        return AbstractMesh(sizes, names)
    except TypeError:  # jax 0.4.x: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(names, sizes)))


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH_MP = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def spec_of(names, shape, mesh=MESH):
    path = tuple(DictKey(n) for n in names)
    leaf = jax.ShapeDtypeStruct(shape, jnp.bfloat16)
    return S.param_spec(path, leaf, mesh)


def test_in_proj_2d_sharded():
    assert spec_of(("seg0", "s0", "attn", "wq"), (23, 4608, 4096)) == \
        P(None, ("data",), "model")


def test_out_proj_transposed():
    assert spec_of(("seg0", "s0", "attn", "wo"), (23, 4096, 4608)) == \
        P(None, "model", ("data",))


def test_multipod_fsdp_axes():
    s = spec_of(("seg0", "s0", "ffn", "wi"), (23, 4608, 36864), MESH_MP)
    assert s == P(None, ("pod", "data"), "model")


def test_moe_experts_over_model():
    s = spec_of(("seg0", "s0", "ffn", "wi"), (94, 128, 4096, 1536))
    assert s == P(None, "model", ("data",), None)


def test_indivisible_dims_replicated():
    # seamless vocab 256206 doesn't divide 16 => replicated on that dim
    s = spec_of(("embed",), (256206, 1024))
    assert s == P(None, ("data",))


def test_norms_replicated():
    assert spec_of(("seg0", "s0", "ln1"), (23, 4608)) == P(None, None)


def test_unstacked_tail_params():
    assert spec_of(("final_norm",), (4608,)) == P(None)


def sds(shape):
    # ShapeDtypeStructs, NOT real arrays — these are full-scale cache shapes
    return jax.ShapeDtypeStruct(shape, jnp.bfloat16)


def test_serving_layout_drops_fsdp():
    tree = {"seg0": {"s0": {"attn": {"wq": sds((2, 64, 64))}}}}
    sh = S.param_shardings(tree, MESH, no_fsdp=True)
    spec = jax.tree.leaves(sh)[0].spec
    assert spec == P(None, None, "model")


def test_cache_split_k_when_heads_indivisible():
    # glm4: kv=2 heads can't shard 16 ways => cache length sharded instead
    cache = {"seg0": {"s0": {"attn": {"k": sds((40, 128, 32768, 2, 128))}}}}
    sh = S.cache_shardings(cache, MESH)
    spec = jax.tree.leaves(sh)[0].spec
    assert spec == P(None, ("data",), "model", None, None)


def test_cache_heads_preferred_when_divisible():
    cache = {"seg0": {"s0": {"attn": {"k": sds((23, 128, 32768, 16, 128))}}}}
    sh = S.cache_shardings(cache, MESH)
    spec = jax.tree.leaves(sh)[0].spec
    assert spec == P(None, ("data",), None, "model", None)
