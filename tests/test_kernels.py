"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(hypothesis property tests; interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.fault_inject.kernel import fault_inject  # noqa: E402
from repro.kernels.fault_inject.ops import inject, random_planes
from repro.kernels.fault_inject.ref import inject_ref
from repro.kernels.protected_mm.kernel import protected_mm
from repro.kernels.protected_mm.ops import calibrate_t, ft_linear_fused
from repro.kernels.protected_mm.ref import protected_mm_ref
from repro.kernels.qmatmul.kernel import qmatmul
from repro.kernels.qmatmul.ops import quant_linear
from repro.kernels.qmatmul.ref import qmatmul_ref

DIMS = st.sampled_from([128, 256, 384])


@settings(max_examples=8, deadline=None)
@given(m=DIMS, k=DIMS, n=st.sampled_from([128, 256]),
       t=st.integers(0, 16), seed=st.integers(0, 1000))
def test_qmatmul_matches_oracle(m, k, n, t, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.randint(k1, (m, k), -127, 128, jnp.int8)
    w = jax.random.randint(k2, (k, n), -127, 128, jnp.int8)
    y = qmatmul(x, w, t)
    yr = qmatmul_ref(x, w, t)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


def test_qmatmul_saturation_active():
    x = jnp.full((128, 512), 127, jnp.int8)
    w = jnp.full((512, 128), 127, jnp.int8)
    y = qmatmul(x, w, 0)       # acc would exceed 24-bit without saturation
    yr = qmatmul_ref(x, w, 0)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    assert int(y.max()) == 127


@settings(max_examples=8, deadline=None)
@given(ber=st.sampled_from([0.0, 0.005, 0.05, 0.3]),
       nb=st.integers(0, 8), seed=st.integers(0, 1000))
def test_fault_inject_matches_oracle(ber, nb, seed):
    M, N = 256, 128
    x = jax.random.randint(jax.random.PRNGKey(seed), (M, N), -128, 128,
                           jnp.int32)
    rnd = random_planes(jax.random.PRNGKey(seed + 1), (M, N))
    prot = jnp.full((N,), nb, jnp.int32)
    y = fault_inject(x, rnd, prot, ber)
    yr = inject_ref(x, rnd, prot, ber)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


def test_fault_inject_protected_bits_invariant():
    M, N = 512, 128
    x = jax.random.randint(jax.random.PRNGKey(0), (M, N), -128, 128,
                           jnp.int32)
    prot = jnp.full((N,), 3, jnp.int32)
    y = inject(jax.random.PRNGKey(1), x, prot, ber=0.4)
    top3 = 0b11100000
    np.testing.assert_array_equal(np.asarray(x) & top3, np.asarray(y) & top3)


def test_fault_inject_deterministic():
    x = jnp.zeros((256, 128), jnp.int32)
    prot = jnp.zeros((128,), jnp.int32)
    y1 = inject(jax.random.PRNGKey(9), x, prot, ber=0.1)
    y2 = inject(jax.random.PRNGKey(9), x, prot, ber=0.1)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


@settings(max_examples=6, deadline=None)
@given(ber=st.sampled_from([0.0, 0.01, 0.1]), t=st.integers(0, 12),
       ib=st.integers(0, 8), seed=st.integers(0, 500))
def test_protected_mm_matches_oracle(ber, t, ib, seed):
    M, K, N = 128, 256, 128
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.randint(ks[0], (M, K), -127, 128, jnp.int8)
    w = jax.random.randint(ks[1], (K, N), -127, 128, jnp.int8)
    ro = random_planes(ks[2], (M, N))
    ri = random_planes(ks[3], (M, N))
    imp = (jnp.arange(N) % 5 == 0).astype(jnp.int32)
    nb = min(1, ib)
    y = protected_mm(x, w, ro, ri, imp, t=t, ber=ber, ib=ib, nb=nb)
    yr = protected_mm_ref(x, w, ro, ri, imp, t=t, ber=ber, ib=ib, nb=nb)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


def test_ft_linear_fused_clean_matches_quant_linear():
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128))
    t = calibrate_t(x, w, q_scale=0)
    y_fused = ft_linear_fused(jax.random.PRNGKey(2), x, w,
                              jnp.zeros((128,), bool), t=t, ber=0.0)
    y_plain = quant_linear(x, w, t)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_plain),
                               rtol=1e-6)


def test_ft_linear_fused_protection_helps():
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128))
    t = calibrate_t(x, w, q_scale=7)
    ref = x @ w

    def dmg(y):
        return float(jnp.sqrt(jnp.mean((y - ref) ** 2)))

    imp = jnp.ones((128,), bool)
    weak = ft_linear_fused(jax.random.PRNGKey(3), x, w, imp, t=t, ber=0.02,
                           ib=0, nb=0)
    strong = ft_linear_fused(jax.random.PRNGKey(3), x, w, imp, t=t, ber=0.02,
                             ib=8, nb=8)
    assert dmg(strong) < dmg(weak)
