"""Elastic rescale: the plan is exact and global-batch-preserving on a
deterministic grid, and the full Trainer closed loop (lose a device ->
plan -> re-mesh -> restore -> continue) reproduces the uninterrupted run
up to gradient-accumulation reordering.

Property-based coverage of the same invariants: tests/test_elastic_props.py.
"""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 2, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_plan_rescale_grid():
    """Every (old_dp, survivors) cell: largest divisor that fits, batch
    preserved exactly."""
    from unittest import mock

    from repro.train.elastic import plan_rescale

    def mesh_like(dp):
        m = mock.Mock()
        m.shape = {"data": dp, "model": 1}
        return m

    # (old_dp, surviving_devices, model_axis) -> (new_dp, scale)
    expect = {
        (4, 4, 1): (4, 1),   # nothing lost: identity plan
        (4, 3, 1): (2, 2),   # 3 survive but 3 does not divide 4 -> dp=2
        (4, 2, 1): (2, 2),
        (4, 1, 1): (1, 4),
        (6, 5, 1): (3, 2),   # 5 doesn't divide 6
        (6, 4, 1): (3, 2),
        (6, 3, 1): (3, 2),
        (6, 2, 1): (2, 3),
        (8, 6, 2): (2, 4),   # model_axis=2: 6 devices fit dp<=3 -> divisor 2
        (8, 16, 2): (8, 1),  # extra capacity is never grown into
    }
    for (old_dp, surv, ax), (dp, scale) in expect.items():
        plan = plan_rescale(mesh_like(old_dp), surv, ax)
        assert (plan.new_dp, plan.grad_accum_scale) == (dp, scale), \
            (old_dp, surv, ax, plan)
        assert plan.new_dp * plan.grad_accum_scale == plan.old_dp
        assert plan.changed == (dp != old_dp)


def test_elastic_closed_loop_matches_uninterrupted(tmp_path):
    """Trainer.handle_device_loss end-to-end on a 2-device host: train to a
    checkpoint on a (2,1) mesh, lose one device, continue on (1,1) with
    grad_accum doubled -- the run must track the uninterrupted 2-device run
    (same global batch; only accumulation order differs)."""
    out = run_py(f"""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import RunConfig, ShapeConfig
        from repro.models import build
        from repro.optim import AdamWConfig
        from repro.train import Trainer, TrainerConfig
        from repro.train.elastic import simulate_device_loss

        cfg = dataclasses.replace(get_config('h2o-danube-1.8b', reduced=True),
                                  unroll=False)
        def model():
            return build(cfg, RunConfig(param_dtype='float32',
                                        compute_dtype='float32'))
        shape = ShapeConfig('tiny', 'train', 64, 8)
        opt = AdamWConfig(lr=1e-3)
        mesh2 = jax.make_mesh((2, 1), ('data', 'model'))

        # uninterrupted reference: 8 steps on the 2-device mesh
        tc_ref = TrainerConfig(total_steps=8, ckpt_every=100, log_every=1000,
                               ckpt_dir='{tmp_path}/ref', ckpt_async=False)
        ref = Trainer(model(), shape, opt, tc_ref, mesh=mesh2)
        s_ref, _ = ref.run()

        # elastic run: ckpt at 4, lose 1 device, continue 4 more on (1,1)
        tc = TrainerConfig(total_steps=4, ckpt_every=4, log_every=1000,
                           ckpt_dir='{tmp_path}/el', ckpt_async=False)
        tr = Trainer(model(), shape, opt, tc, mesh=mesh2)
        tr.run()
        survivors = simulate_device_loss(tr.mesh, 1)
        assert len(survivors) == 1
        state, step = tr.handle_device_loss(survivors)
        assert step == 4
        assert tr.mesh.shape['data'] == 1
        assert tr.model.run.grad_accum == 2   # global batch preserved
        tr.cfg.total_steps = 8
        s_el, end = tr.run(state, step)
        assert end == 8

        # pull both states off their (different) meshes before comparing
        d = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                for a, b in zip(
            jax.tree.leaves(s_ref['params']), jax.tree.leaves(s_el['params'])))
        l_ref = ref.metrics_log[-1]['loss']
        l_el = tr.metrics_log[-1]['loss']
        print('PARAMDIFF', d, 'LOSSDIFF', abs(l_ref - l_el))
        assert d < 5e-2, d
        assert abs(l_ref - l_el) < 5e-2, (l_ref, l_el)
        print('OK')
    """)
    assert "OK" in out
