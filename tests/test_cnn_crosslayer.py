"""End-to-end paper pipeline on the reduced CNN benchmarks: train -> layer
sensitivity -> selective protection -> accuracy recovery (the system-level
claims of Figs. 5-7).

Operating point: these margins were carried as known failures since the seed.
The root cause was NOT under-training (the VGG hit train/eval accuracy 1.000
in 200 steps) and NOT the thresholds: at the original data noise 0.4 the
procedural template task is separable with such wide logit margins that
BER 2e-3 faults moved accuracy by only ~0.023 (< the 0.03 margin) and the
per-layer sensitivity spread collapsed to ~0.007 (< 0.01) — the paper's
CIFAR benchmarks live near 0.9 clean accuracy, where faults visibly bite.
The fix raises the benchmark's data noise to 1.6 (train_cnn / CnnOracle
defaults), putting clean accuracy at ~0.98: measured there, BER 2e-3
degrades accuracy by ~0.17 and the layer spread is ~0.065, so the margins
below test the paper's actual claims with real headroom."""
import numpy as np
import pytest

from repro.core.evaluate import trained_cnn
from repro.core.flexhyca import FTConfig


@pytest.fixture(scope="module")
def vgg():
    return trained_cnn("vgg", steps=200)


def test_cnn_trains_above_chance(vgg):
    assert vgg.clean_acc > 0.6  # 8 classes => chance 0.125


def test_faults_degrade_accuracy(vgg):
    clean = vgg.accuracy(None)
    faulty = vgg.accuracy(FTConfig(ber=2e-3, strategy="base"))
    assert faulty < clean - 0.03


def test_layer_sensitivity_differs(vgg):
    sens = vgg.layer_sensitivity(ber=2e-3)
    vals = np.array(list(sens.values()))
    assert vals.max() - vals.min() > 0.01  # Fig. 5: layers differ


def test_cumulative_protection_monotoneish(vgg):
    curve = vgg.cumulative_protection(ber=2e-3)
    accs = [a for _, a in curve]
    assert accs[-1] > accs[0]  # protecting everything recovers accuracy


def test_cl_strategy_recovers_accuracy(vgg):
    ber = 2e-3
    base = vgg.accuracy(FTConfig(ber=ber, strategy="base"))
    cl = vgg.accuracy(FTConfig(ber=ber, strategy="cl", s_th=0.1, ib_th=4,
                               nb_th=2, q_scale=4))
    crt3 = vgg.accuracy(FTConfig(ber=ber, strategy="crt3"))
    assert cl > base + 0.02
    assert crt3 > base


def test_resnet_variant_trains():
    o = trained_cnn("resnet", steps=200)
    assert o.clean_acc > 0.5
