from repro.core import area as A


def test_pp_counts():
    assert A.pp_count(0) == 1
    assert A.pp_count(7) == 8
    assert A.pp_count(14) == 1
    assert A.pp_count(15) == 0
    assert sum(A.pp_count(c) for c in range(16)) == 64  # 8x8 partial products


def test_important_columns_match_paper_examples():
    # paper Fig. 2: s=2 unconstrained => multiplier columns 6..15
    assert A.important_columns(2, 0) == (6, 15)
    # paper: Q_scale=5, s=2 => columns 11..15
    assert A.important_columns(2, 5) == (11, 15)


def test_quant_constraint_shrinks_protected_region():
    for s in (1, 2, 3):
        lo0, hi0 = A.important_columns(s, 0)
        lo7, hi7 = A.important_columns(s, 7)
        assert hi0 - lo0 >= hi7 - lo7


def test_direct_vs_configurable():
    for s in (1, 2, 3):
        for q in (0, 4, 7):
            d = A.bit_protect_cost(s, q, "direct").total
            c = A.bit_protect_cost(s, q, "configurable").total
            assert c <= d * 1.05  # configurable never meaningfully worse


def test_full_tmr_is_about_3x():
    r = A.full_tmr_pe_cost() / A.pe_cost()
    assert 3.0 <= r <= 3.5


def test_paper_71_percent_reduction_claim():
    """Constrained reconfigurable redundancy ~71.4% below unconstrained
    direct (paper Section IV-E) — we accept 60-85%."""
    reductions = []
    for s in (1, 2, 3):
        d0 = A.bit_protect_cost(s, 0, "direct").total
        c7 = A.bit_protect_cost(s, 7, "configurable").total
        reductions.append(1 - c7 / d0)
    avg = sum(reductions) / len(reductions)
    assert 0.60 <= avg <= 0.85, avg


def test_area_monotone_in_bits():
    costs = [A.bit_protect_cost(s, 4, "configurable").total
             for s in (1, 2, 3, 4)]
    assert costs == sorted(costs)


def test_array_area_breakdown():
    r = A.array_area(32, nb_th=1, q_scale=7, pe_policy="configurable",
                     dot_size=52, ib_th=2)
    assert r["overhead"] > 0
    assert r["dppu"] < r["array"]  # DPPU much smaller than the 2-D array
    # paper: low-protection settings keep overhead small (<40%)
    assert r["overhead"] < 0.4


def test_dppu_bits_cheap_array_bits_costly():
    """Fig. 12: raising IB_TH (DPPU) is much cheaper than raising NB_TH."""
    base = A.array_area(32, 1, 7, "configurable", 52, 2)["overhead"]
    up_ib = A.array_area(32, 1, 7, "configurable", 52, 4)["overhead"]
    up_nb = A.array_area(32, 3, 7, "configurable", 52, 2)["overhead"]
    assert up_ib - base < (up_nb - base) / 4
