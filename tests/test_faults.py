import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import faults  # noqa: E402


def test_zero_ber_identity():
    x = jax.random.randint(jax.random.PRNGKey(0), (100,), -128, 128)
    out = faults.flip_bits(jax.random.PRNGKey(1), x, 0.0, 8)
    assert (np.asarray(out) == np.asarray(x)).all()


def test_flip_rate_statistics():
    n = 20000
    x = jnp.zeros((n,), jnp.int32)
    ber = 0.02
    out = faults.flip_bits(jax.random.PRNGKey(2), x, ber, 8)
    rate = float(jnp.mean(out != 0))
    expect = 1 - (1 - ber) ** 8
    assert abs(rate - expect) < 0.01


def test_protected_bits_use_residual_rate():
    n = 50000
    x = jnp.zeros((n,), jnp.int32)
    ber = 0.05
    mask = faults.top_bits_mask(8, 8)  # everything protected
    out = faults.flip_bits(jax.random.PRNGKey(3), x, ber, 8,
                           protected_mask=mask)
    rate = float(jnp.mean(out != 0))
    expect = 1 - (1 - faults.residual_ber(ber)) ** 8
    unprotected = 1 - (1 - ber) ** 8
    assert abs(rate - expect) < 0.005
    assert rate < unprotected / 3  # protection must actually help


def test_sign_extension():
    x = jnp.asarray([-1], jnp.int32)  # 0xFF in 8 bits
    out = faults.flip_bits(jax.random.PRNGKey(0), x, 0.0, 8)
    assert int(out[0]) == -1


def test_top_bits_mask():
    assert faults.top_bits_mask(2, 8) == 0b11000000
    assert faults.top_bits_mask(0, 8) == 0
    assert faults.top_bits_mask(8, 8) == 0xFF


@settings(max_examples=15, deadline=None)
@given(nb=st.integers(0, 8), seed=st.integers(0, 1000))
def test_per_channel_protection(nb, seed):
    """High `nb` bits of each output never flip at raw BER (residual only)."""
    n, c = 512, 16
    x = jnp.zeros((n, c), jnp.int32)
    prot = jnp.full((c,), nb, jnp.int32)
    out = faults.inject_output_faults(jax.random.PRNGKey(seed), x, 0.5,
                                      protect_top=prot)
    mask = faults.top_bits_mask(nb, 8)
    flipped_prot = np.asarray(out) & mask
    # residual rate at ber=.5: 3*.25*.5+.125 = .5 — degenerate; use lower ber
    out2 = faults.inject_output_faults(jax.random.PRNGKey(seed), x, 0.01,
                                       protect_top=prot)
    rate_prot = float(np.mean((np.asarray(out2) & mask) != 0)) if nb else 0.0
    assert rate_prot <= 8 * faults.residual_ber(0.01) + 0.01


def test_importance_protection_reduces_damage():
    """More protected bits => smaller numeric damage (paper's bit dimension)."""
    x = jax.random.randint(jax.random.PRNGKey(1), (2000,), -100, 100)
    dmg = []
    for nb in (0, 2, 4, 8):
        out = faults.inject_output_faults(
            jax.random.PRNGKey(2), x, 0.05,
            protect_top=jnp.full((x.shape[0],), nb, jnp.int32) if False
            else nb)
        dmg.append(float(jnp.mean(jnp.abs(out - x))))
    assert dmg[0] > dmg[1] > dmg[3] or dmg[0] > dmg[3]
