import os
import sys

# single-device for smoke tests (the dry-run forces 512 in its own process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    """JIT executables accumulate across the ~190-test suite (hypothesis
    sweeps + many static FT configs) to tens of GB; bound it per module."""
    yield
    jax.clear_caches()
