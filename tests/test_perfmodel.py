from repro.core import perfmodel as P


def layers():
    return P.lm_layer_gemms(6, 256, 1024, 8, 32, 8, seq=512,
                            sensitive_frac=0.5)


def test_base_and_crt_no_perf_loss():
    cfg = P.DlaConfig(array_dim=32, dot_size=52)
    assert P.perf_loss(layers(), cfg, "base") == 0.0
    assert P.perf_loss(layers(), cfg, "crt") == 0.0


def test_alg_tmr_triples_sensitive_layers():
    cfg = P.DlaConfig(array_dim=32)
    loss = P.perf_loss(layers(), cfg, "alg")
    # half the layers 3x => total ~2x => loss ~1.0 (paper: "nearly double")
    assert 0.7 <= loss <= 1.3


def test_arch_tmr_similar_to_alg():
    cfg = P.DlaConfig(array_dim=32)
    l_arch = P.perf_loss(layers(), cfg, "arch")
    l_alg = P.perf_loss(layers(), cfg, "alg")
    assert abs(l_arch - l_alg) < 0.6


def test_cl_negligible_with_adequate_dppu():
    cfg = P.DlaConfig(array_dim=32, dot_size=64)
    assert P.perf_loss(layers(), cfg, "cl", s_th=0.05) < 0.05


def test_cl_degrades_with_tiny_dppu():
    cfg = P.DlaConfig(array_dim=32, dot_size=1)
    big = P.perf_loss(layers(), cfg, "cl", s_th=0.4)
    assert big > 0.0


def test_io_linear_in_s_th():
    """Fig. 13: extra IO grows with S_TH and crosses ~10% near S_TH=0.1."""
    cfg = P.DlaConfig(array_dim=32, dot_size=52, data_reuse=True)
    ratios = [P.io_bytes(layers(), cfg, "cl", s_th=s)["extra_over_weights"]
              for s in (0.02, 0.05, 0.1, 0.2)]
    assert ratios == sorted(ratios)
    assert ratios[2] > 0.05  # near or above 10% at s_th=0.1


def test_data_reuse_reduces_io():
    cfg_r = P.DlaConfig(array_dim=32, dot_size=52, data_reuse=True)
    cfg_n = P.DlaConfig(array_dim=32, dot_size=52, data_reuse=False)
    r = P.io_bytes(layers(), cfg_r, "cl", s_th=0.1)["extra_over_weights"]
    n = P.io_bytes(layers(), cfg_n, "cl", s_th=0.1)["extra_over_weights"]
    assert r < n
