"""End-to-end behaviour tests: the full cross-layer optimization pipeline
(paper Fig. 1) from sensitivity analysis through Bayesian DSE."""
import pytest

from repro.core import bayesopt as B
from repro.core import perfmodel as P
from repro.core.evaluate import trained_cnn
from repro.core.flexhyca import FTConfig
from repro.core.pipeline import optimize
from repro.core.strategies import make_strategies


@pytest.fixture(scope="module")
def oracle():
    return trained_cnn("vgg", steps=200)


@pytest.fixture(scope="module")
def workload():
    return P.lm_layer_gemms(4, 128, 512, 4, 32, 4, seq=256)


def test_full_crosslayer_pipeline(oracle, workload):
    """Run the complete DSE for fault-rate-I-style constraints and check the
    selected design dominates blanket TMR on area at equal feasibility."""
    clean = oracle.accuracy(None)
    ber = 1e-3
    cons = B.Constraints(acc_min=0.97 * clean, perf_max=0.10, bw_max=0.10)

    space = [
        B.Param("s_th", (0.05, 0.1, 0.2), monotone=+1),
        B.Param("ib_th", (2, 3, 4), monotone=+1),
        B.Param("nb_th", (1, 2, 3), monotone=+1),
        B.Param("q_scale", (4, 7), monotone=0),
        B.Param("s_policy", ("uniform",), monotone=0),
        B.Param("dot_size", (16, 52), monotone=0),
        B.Param("data_reuse", (True,), monotone=0),
        B.Param("pe_policy", ("configurable", "direct"), monotone=0),
    ]
    res = optimize(lambda ft: oracle.accuracy(ft), workload, cons, ber,
                   iter_max_step=14, seed=0, space=space)
    assert res.ft is not None, "DSE found no feasible design"
    # paper Fig. 9: cross-layer design is far below full TMR (200%)
    assert res.area_overhead < 2.0
    # and the chosen design really meets the accuracy bar
    acc = oracle.accuracy(res.ft)
    assert acc >= 0.97 * clean - 0.03


def test_strategy_comparison_matches_paper(oracle, workload):
    """Fig. 7/8/9 qualitative relations on the reduced benchmark."""
    strategies = make_strategies()
    ber = 1e-3
    area = {k: s.area_relative() for k, s in strategies.items()}
    perf = {k: s.perf_loss(workload) for k, s in strategies.items()}
    # area: crt3 > crt2 > crt1 > arch >= alg == base
    assert area["crt3"] > area["crt2"] > area["crt1"] > area["arch"]
    assert area["alg"] == 1.0 and area["base"] == 1.0
    # perf: alg/arch suffer heavily, cl and crt do not
    assert perf["alg"] > 0.5 and perf["arch"] > 0.5
    assert perf["cl"] < 0.05 and perf["crt1"] == 0.0
    # accuracy: any protection beats none at this BER
    acc_base = oracle.accuracy(FTConfig(ber=ber, strategy="base"))
    acc_crt3 = oracle.accuracy(FTConfig(ber=ber, strategy="crt3"))
    assert acc_crt3 > acc_base
