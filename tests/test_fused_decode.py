"""Fused inject->protect->qmatmul decode kernel: bit-exactness against the
composed reference ops (docs/kernels.md documents the contract).

Compile-cost discipline: every distinct *static* kernel structure (policy
metadata, per-row flag, weight-fault routing) costs a fresh interpret-mode
compile, so the sweep varies BER / q_scale / shapes on the *trace* (free)
and bounds the number of distinct structures.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ft
from repro.core import faults
from repro.core import quantization as Q
from repro.kernels.fused_decode.kernel import fused_decode
from repro.kernels.fused_decode.ref import fused_ref

POLICIES = ("base", "crt1", "crt2", "crt3", "arch", "alg", "cl")


def _xw(m, k, n, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(kx, (m, k), jnp.float32),
            jax.random.normal(kw, (k, n), jnp.float32))


def _assert_bitwise(a, b, msg):
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype and a.shape == b.shape, msg
    if not (a == b).all():
        i = np.unravel_index(np.argmax(a != b), a.shape)
        raise AssertionError(f"{msg}: first mismatch at {i}: "
                             f"{a[i]!r} != {b[i]!r}")


def test_kernel_matches_ref_triplet():
    """The pallas kernel and kernels/fused_decode/ref.py agree bitwise on
    raw integer operands — including multi-block K accumulation, packed
    per-row weight flip words, and the DPPU clean-recompute select."""
    key = jax.random.PRNGKey(3)
    m, k, n = 8, 256, 128          # grid of 2 K-blocks
    ks = jax.random.split(key, 8)
    xq = jax.random.randint(ks[0], (m, k), -128, 128, jnp.int32
                            ).astype(jnp.int8)
    wq = jax.random.randint(ks[1], (k, n), -128, 128, jnp.int32
                            ).astype(jnp.int8)
    oflips = faults.flip_word(ks[2], (m, n), 1e-2, Q.OUT_BITS)
    qs = jnp.zeros((1, 1), jnp.int32)

    # plain: no weight faults, no DPPU
    y, t = fused_decode(xq, wq, oflips, qs, per_row=False, dppu_src="none",
                        perrow_wf=False)
    yr, tr = fused_ref(xq, wq, oflips, q_scale=0, per_row=False)
    _assert_bitwise(y, yr.astype(jnp.int8), "plain yq")
    _assert_bitwise(t[0, 0], jnp.asarray(tr, jnp.int32), "plain t")

    # per-row + per-row weight flips + DPPU recompute from the clean w
    wflips = jax.vmap(lambda kk: faults.flip_word(
        kk, (k, n), 5e-3, Q.OUT_BITS))(jax.random.split(ks[3], m))
    dflips = faults.flip_word(ks[4], (m, n), 5e-3, Q.OUT_BITS)
    imp = (jax.random.uniform(ks[5], (n,)) < 0.5)
    y2, t2 = fused_decode(xq, wq, oflips, qs, wflips=wflips, dflips=dflips,
                          imp=imp.astype(jnp.int32).reshape(1, n),
                          per_row=True, dppu_src="w", perrow_wf=True)
    y2r, t2r = fused_ref(xq, wq, oflips, q_scale=0, per_row=True,
                         wflips=wflips, dflips=dflips, imp=imp)
    _assert_bitwise(y2, y2r.astype(jnp.int8), "per-row yq")
    _assert_bitwise(t2[:, 0], jnp.ravel(t2r).astype(jnp.int32), "per-row t")


@pytest.mark.parametrize("policy_name", POLICIES)
def test_fused_matches_reference_policy_sweep(policy_name):
    """For every registry policy, backend='fused' equals the reference
    backend BITWISE.  BER, dyn q_scale, and shapes vary on the trace inside
    one compiled structure per (policy, shape) pair; shapes include odd /
    non-8/128-divisible sizes exercising the tile-padding path."""
    imp_key = jax.random.PRNGKey(9)
    for shape_i, (m, k, n) in enumerate(((5, 70, 57), (9, 200, 130))):
        x, w = _xw(m, k, n, seed=shape_i)
        important = jax.random.uniform(
            jax.random.fold_in(imp_key, shape_i), (n,)) < 0.3
        for ber in (1e-3, 1e-2):
            for qs in (0, 3):
                policy = ft.get_policy(policy_name, ber=ber,
                                       weight_faults=True)
                key = jax.random.fold_in(jax.random.PRNGKey(11),
                                         shape_i * 100 + qs)
                args = (key, x, w, policy, important)
                dyn = {"q_scale": jnp.asarray(qs, jnp.int32)}
                y_ref = ft.protect_linear(*args, backend="reference",
                                          dyn=dyn)
                y_fus = ft.protect_linear(*args, backend="fused", dyn=dyn)
                _assert_bitwise(
                    y_ref, y_fus,
                    f"{policy_name} ber={ber} qs={qs} shape={(m, k, n)}")


def test_fused_matches_reference_per_row():
    """Per-row key batches (the serving path): each row's fault stream —
    including its private faulty-weight view — matches the reference."""
    m, k, n = 6, 70, 57
    x, w = _xw(m, k, n, seed=7)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(100, 100 + m))
    important = jax.random.uniform(jax.random.PRNGKey(1), (n,)) < 0.3
    for pname in ("crt2", "cl"):           # plain ECC + DPPU-recompute
        policy = ft.get_policy(pname, ber=5e-3, weight_faults=True)
        y_ref = ft.protect_linear(keys, x, w, policy, important,
                                  backend="reference")
        y_fus = ft.protect_linear(keys, x, w, policy, important,
                                  backend="fused")
        _assert_bitwise(y_ref, y_fus, f"per-row {pname}")
    # row independence: swapping a neighbour's key leaves other rows alone
    policy = ft.get_policy("crt2", ber=5e-3, weight_faults=True)
    keys2 = keys.at[0].set(jax.random.PRNGKey(999))
    y_a = ft.protect_linear(keys, x, w, policy, backend="fused")
    y_b = ft.protect_linear(keys2, x, w, policy, backend="fused")
    _assert_bitwise(y_a[1:], y_b[1:], "rows 1.. perturbed by row 0 key")
    assert not np.array_equal(np.asarray(y_a[0]), np.asarray(y_b[0]))


def test_engine_token_parity_reference_vs_fused():
    """End to end: serve.Engine at temperature 0 emits identical tokens with
    ft_backend='reference' and ft_backend='fused' (weight faults on)."""
    from repro.configs import get_config
    from repro.models import build
    from repro.serve.engine import Engine, ServeConfig

    cfg = get_config("h2o-danube-1.8b", reduced=True)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 6),
                                          0, cfg.vocab)}
    policy = ft.get_policy("cl", ber=3e-3, weight_faults=True)
    toks = {}
    for backend in ("reference", "fused"):
        eng = Engine(m, params, cfg=ServeConfig(max_new_tokens=6),
                     policy=policy, ft_backend=backend)
        toks[backend] = np.asarray(eng.generate(batch, seed=0))
    _assert_bitwise(toks["reference"], toks["fused"], "engine tokens")
