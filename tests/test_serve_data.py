import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, LMIterator, lm_batch, make_batch, vision_batch
from repro.models import build
from repro.serve.engine import Engine, ServeConfig


def test_engine_generates_deterministically():
    cfg = get_config("h2o-danube-1.8b", reduced=True)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = Engine(m, params, cfg=ServeConfig(max_new_tokens=8))
    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12),
                                            0, cfg.vocab)}
    out1 = eng.generate(prompts)
    eng2 = Engine(m, params, cfg=ServeConfig(max_new_tokens=8))
    out2 = eng2.generate(prompts)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_engine_beyond_window():
    """Generation runs past the SWA window (rolling cache wraps)."""
    cfg = get_config("h2o-danube-1.8b", reduced=True)  # window 16
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = Engine(m, params, cfg=ServeConfig(max_new_tokens=24))
    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 8),
                                            0, cfg.vocab)}
    out = eng.generate(prompts)
    assert out.shape == (1, 24)
    assert int(out.max()) < cfg.vocab


def test_ssm_engine_generates():
    cfg = get_config("mamba2-2.7b", reduced=True)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = Engine(m, params, cfg=ServeConfig(max_new_tokens=6))
    out = eng.generate({"tokens": jnp.ones((2, 9), jnp.int32)})
    assert out.shape == (2, 6)


# ------------------------------------------------------------------ data --
def test_lm_batch_deterministic_and_structured():
    d = DataConfig(noise=0.0)
    b1 = lm_batch(d, 128, 4, 64, step=3)
    b2 = lm_batch(d, 128, 4, 64, step=3)
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    # noiseless streams are periodic: token[t] == token[t - period]
    toks = np.asarray(b1)
    ok = 0
    for row in toks:
        for p in range(d.min_period, d.max_period + 1):
            if (row[p:] == row[:-p]).all():
                ok += 1
                break
    assert ok == toks.shape[0]


def test_host_sharding_partitions_batch():
    d = DataConfig()
    full = lm_batch(d, 128, 8, 32, step=0)
    parts = [lm_batch(d, 128, 8, 32, step=0, process_index=i,
                      process_count=4) for i in range(4)]
    assert all(p.shape == (2, 32) for p in parts)


def test_iterator_resume():
    cfg = get_config("h2o-danube-1.8b", reduced=True)
    shape = ShapeConfig("t", "train", 32, 4)
    it = LMIterator(cfg, shape)
    next(it); next(it)
    state = it.state()
    b3 = next(it)
    it2 = LMIterator(cfg, shape)
    it2.restore(state)
    b3b = next(it2)
    np.testing.assert_array_equal(np.asarray(b3["tokens"]),
                                  np.asarray(b3b["tokens"]))


def test_vision_batch_learnable():
    imgs, labels = vision_batch(jax.random.PRNGKey(0), 64)
    assert imgs.shape == (64, 16, 16, 1)
    # same-class images correlate more than cross-class
    same = cross = 0.0
    v = np.asarray(imgs).reshape(64, -1)
    lab = np.asarray(labels)
    corr = np.corrcoef(v)
    same = np.mean([corr[i, j] for i in range(64) for j in range(i + 1, 64)
                    if lab[i] == lab[j]])
    cross = np.mean([corr[i, j] for i in range(64) for j in range(i + 1, 64)
                     if lab[i] != lab[j]])
    assert same > cross + 0.2


def test_make_batch_aux_streams_independent():
    """patch_embeds and frames must come from distinct key derivations:
    with a shared key, equal shapes made them bit-identical (FTL001)."""
    import dataclasses
    m = dataclasses.replace(get_config("paligemma-3b", reduced=True),
                            frontend="vision", n_frontend_tokens=16,
                            enc_dec=True)
    b = make_batch(m, ShapeConfig("t", "train", 16, 4), step=0)
    assert not np.array_equal(np.asarray(b["patch_embeds"]),
                              np.asarray(b["frames"]))
