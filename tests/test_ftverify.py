"""ftverify rule tests: per-rule seeded-bad fixtures (a jaxpr that violates
the contract must be flagged), clean fixtures (the sanctioned idiom stays
quiet), and the acceptance gates — the repo's own protect targets verify
clean, and test-local reverts of the PR 9 fixes (the threefry flag, the
post-rope constraint) are caught.

Fixtures are traced inline with ``jax.make_jaxpr``; nothing here executes
on device, so the whole battery runs in single-device CI.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from tools.ftverify import ALL_RULES, VerifyEnv, build_graph, verify_targets
from tools.ftverify.core import Target, TargetCtx
from tools.ftverify.rules import FTV102, FTV103, FTV105, FTV106
from tools.ftverify.rules.ftv101_int_datapath import (
    check_backward_slices, check_injected_roundtrips)
from tools.ftverify.rules.ftv102_partition import (
    PARTITIONABLE_MARKER, find_bf16_roundtrips, probe_threefry_lowering)
from tools.ftverify.rules.ftv103_key_streams import (check_reuse,
                                                     check_scan_invariance)
from tools.ftverify.rules.ftv104_one_executable import check_policy_leaves
from tools.ftverify.rules.ftv105_donation import count_aliased_inputs
from tools.ftverify.rules.ftv106_sharding import (check_rope_constraints,
                                                  find_rope_concats)

_sds = jax.ShapeDtypeStruct
ENV = VerifyEnv(excess_precision_pinned=True, threefry_partitionable=True,
                n_devices=1)


def graph_of(fn, *avals):
    return build_graph(jax.make_jaxpr(fn)(*avals))


def fnd(scope, msg):
    return (scope, msg)


def key_aval(batch=None):
    return _sds(((batch, 2) if batch else (2,)), jnp.uint32)


_DN = (((1,), (0,)), ((), ()))


# ------------------------------------------------------------------ FTV101 --
def test_ftv101_flags_float_excursion_into_truncation():
    def bad(x, w):
        acc = jax.lax.dot_general(x, w, _DN,
                                  preferred_element_type=jnp.int32)
        y = (acc.astype(jnp.float32) * 1.25).astype(jnp.int32)
        return jax.lax.shift_right_arithmetic(y, 3)

    g = graph_of(bad, _sds((4, 8), jnp.int32), _sds((8, 8), jnp.int32))
    out = check_backward_slices(g, fnd)
    assert len(out) == 1
    assert "float 'mul'" in out[0][1]


def test_ftv101_flags_narrow_integer_accumulation():
    def bad(x, w):
        acc = jax.lax.dot_general(x, w, _DN,
                                  preferred_element_type=jnp.int16)
        return jax.lax.shift_right_arithmetic(acc, 2)

    g = graph_of(bad, _sds((4, 8), jnp.int16), _sds((8, 8), jnp.int16))
    out = check_backward_slices(g, fnd)
    assert len(out) == 1
    assert "<32 bits" in out[0][1]


def test_ftv101_clean_integer_slice():
    def ok(x, w):
        acc = jax.lax.dot_general(x, w, _DN,
                                  preferred_element_type=jnp.int32)
        return jax.lax.shift_right_arithmetic(acc + 4, 3)

    g = graph_of(ok, _sds((4, 8), jnp.int8), _sds((8, 8), jnp.int8))
    assert check_backward_slices(g, fnd) == []


def test_ftv101_flags_injected_float_roundtrip():
    def bad(y, flips):
        z = (y ^ flips).astype(jnp.float32) * 2.0
        return z.astype(jnp.int32)

    g = graph_of(bad, _sds((8,), jnp.int32), _sds((8,), jnp.int32))
    out = check_injected_roundtrips(g, fnd)
    assert len(out) == 1
    assert "float round-trip" in out[0][1]


def test_ftv101_round_sanctions_the_requantize():
    def ok(y, flips):
        z = (y ^ flips).astype(jnp.float32) * 2.0
        return jnp.round(z).astype(jnp.int32)

    g = graph_of(ok, _sds((8,), jnp.int32), _sds((8,), jnp.int32))
    assert check_injected_roundtrips(g, fnd) == []


# ------------------------------------------------------------------ FTV102 --
def test_ftv102_finds_bf16_roundtrip_pairs():
    def f(x):
        return x.astype(jnp.bfloat16).astype(jnp.float32) * 2.0

    g = graph_of(f, _sds((8,), jnp.float32))
    assert len(find_bf16_roundtrips(g)) == 1


def test_ftv102_fires_only_when_excess_precision_unpinned():
    def f(x):
        return x.astype(jnp.bfloat16).astype(jnp.float32) * 2.0

    t = Target("fixture.bf16", frozenset(),
               trace=lambda: jax.make_jaxpr(f)(_sds((8,), jnp.float32)))
    assert FTV102.check_target(TargetCtx(t, ENV)) == []
    unpinned = VerifyEnv(excess_precision_pinned=False,
                         threefry_partitionable=True, n_devices=1)
    out = FTV102.check_target(TargetCtx(t, unpinned))
    assert [f.code for f in out] == ["FTV102"]
    assert "excess_precision" in out[0].message


def test_ftv102_catches_threefry_flag_revert():
    """Reverting the PR 9 partitionable-threefry pin must be caught."""
    import repro.core.faults  # noqa: F401 — pins the flag at import
    assert jax.config.jax_threefry_partitionable
    try:
        jax.config.update("jax_threefry_partitionable", False)
        out = FTV102.check_global(VerifyEnv.capture())
        assert [f.code for f in out] == ["FTV102"]
        assert "partition-variant" in out[0].message
        # the lowering really is the legacy (non-partitionable) form
        assert PARTITIONABLE_MARKER not in probe_threefry_lowering()
    finally:
        jax.config.update("jax_threefry_partitionable", True)
    assert FTV102.check_global(VerifyEnv.capture()) == []
    assert PARTITIONABLE_MARKER in probe_threefry_lowering()


# ------------------------------------------------------------------ FTV103 --
def test_ftv103_flags_laundered_key_reuse():
    def bad(k):
        a = jax.random.uniform(k, (4,))
        b = jax.random.uniform(jnp.reshape(k, (2,)), (4,))
        return a + b

    g = graph_of(bad, key_aval())
    out = check_reuse(g, fnd)
    assert len(out) == 1
    assert "same fault stream" in out[0][1]


def test_ftv103_distinct_fold_in_paths_clean():
    def ok(k):
        a = jax.random.uniform(jax.random.fold_in(k, 0), (4,))
        b = jax.random.uniform(jax.random.fold_in(k, 1), (4,))
        return a + b

    g = graph_of(ok, key_aval())
    assert check_reuse(g, fnd) == []


def test_ftv103_flags_scan_closed_over_key():
    def bad(k, xs):
        def body(c, x):
            return c + jax.random.uniform(k, ()), x
        return jax.lax.scan(body, 0.0, xs)

    g = graph_of(bad, key_aval(), _sds((4,), jnp.float32))
    out = check_scan_invariance(g, fnd)
    assert len(out) == 1
    assert "replayed every loop iteration" in out[0][1]


def test_ftv103_scan_key_folded_from_xs_clean():
    def ok(k, xs):
        def body(c, i):
            kk = jax.random.fold_in(k, i)
            return c + jax.random.uniform(kk, ()), i
        return jax.lax.scan(body, 0.0, xs)

    g = graph_of(ok, key_aval(), _sds((4,), jnp.int32))
    assert check_scan_invariance(g, fnd) == []


# ------------------------------------------------------------------ FTV104 --
def test_ftv104_flags_multi_leaf_policy(monkeypatch):
    @jax.tree_util.register_pytree_node_class
    class TwoLeafPolicy:
        def __init__(self, ber, s_th):
            self.ber, self.s_th = ber, s_th

        def tree_flatten(self):
            return (self.ber, self.s_th), None

        @classmethod
        def tree_unflatten(cls, aux, leaves):
            return cls(*leaves)

    import repro.ft as ft
    monkeypatch.setattr(ft, "list_policies", lambda: ["bad2"])
    monkeypatch.setattr(ft, "get_policy",
                        lambda name, **kw: TwoLeafPolicy(1e-3, 0.5))
    out = check_policy_leaves(fnd)
    assert len(out) == 1
    assert "2 leaves" in out[0][1]


# ------------------------------------------------------------------ FTV105 --
def test_ftv105_flags_dropped_donation():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # jax warns on the unusable donation
        hlo = jax.jit(lambda c, x: (c + x).sum(), donate_argnums=(0,)).lower(
            _sds((8,), jnp.float32), _sds((8,), jnp.float32)).as_text()
    assert count_aliased_inputs(hlo) == 0
    t = Target("fixture.dropped", frozenset(), lower=lambda: hlo,
               donated_leaves=1)
    out = FTV105.check_target(TargetCtx(t, ENV))
    assert [f.code for f in out] == ["FTV105"]
    assert "silently dropped" in out[0].message


def test_ftv105_landed_donation_clean():
    hlo = jax.jit(lambda c, x: c + x, donate_argnums=(0,)).lower(
        _sds((8,), jnp.float32), _sds((8,), jnp.float32)).as_text()
    assert count_aliased_inputs(hlo) >= 1
    t = Target("fixture.landed", frozenset(), lower=lambda: hlo,
               donated_leaves=1)
    assert FTV105.check_target(TargetCtx(t, ENV)) == []


# ------------------------------------------------------------------ FTV106 --
def _rope_like(x):
    c, s = jnp.cos(x), jnp.sin(x)
    lo, hi = x[:, :2], x[:, 2:]
    return jnp.concatenate([lo * c[:, :2] - hi * s[:, 2:],
                            hi * c[:, 2:] + lo * s[:, :2]], axis=-1)


def test_ftv106_finds_rope_concats():
    g = graph_of(_rope_like, _sds((4, 4), jnp.float32))
    assert len(find_rope_concats(g)) == 1


def test_ftv106_flags_unconstrained_rope_into_dot():
    def bad(x, w):
        return _rope_like(x) @ w

    g = graph_of(bad, _sds((4, 4), jnp.float32), _sds((4, 4), jnp.float32))
    out = check_rope_constraints(g, fnd)
    assert len(out) == 1
    assert "sharding_constraint" in out[0][1]


def test_ftv106_constrained_rope_clean():
    from jax.sharding import Mesh, NamedSharding, PartitionSpec
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sh = NamedSharding(mesh, PartitionSpec(None, None))

    def ok(x, w):
        r = jax.lax.with_sharding_constraint(_rope_like(x), sh)
        return r @ w

    g = graph_of(ok, _sds((4, 4), jnp.float32), _sds((4, 4), jnp.float32))
    assert check_rope_constraints(g, fnd) == []


def test_ftv106_catches_post_rope_constraint_revert(monkeypatch):
    """Test-locally revert PR 9's post-rope re-constraint (neutralize the
    ``ac`` helper inside attention) and verify FTV106 fires on the traced
    mesh prefill; unpatched, the same target is clean."""
    import repro.models.attention as attn
    from tools.ftverify.targets import _engine_targets

    def mesh_prefill():
        for t in _engine_targets():
            if t.name == "engine.prefill.mesh":
                return t
        raise AssertionError("engine.prefill.mesh missing from manifest")

    t = mesh_prefill()
    assert FTV106.check_target(TargetCtx(t, ENV)) == []

    monkeypatch.setattr(attn, "ac", lambda x, *axes: x)
    out = FTV106.check_target(TargetCtx(mesh_prefill(), ENV))
    assert out and all(f.code == "FTV106" for f in out)
    assert any("post-rope" in f.scope for f in out)


# --------------------------------------------------------------- machinery --
def test_findings_use_stable_trace_paths():
    t = Target("some.target", frozenset())
    f = TargetCtx(t, ENV).finding("FTV101", "truncation", "msg")
    assert f.path == "trace://some.target" and f.line == 0
    assert f.baseline_key() == "FTV101 trace://some.target::truncation::msg"


def test_crashing_target_reports_ftv000_not_abort():
    def boom():
        raise RuntimeError("trace exploded")

    t = Target("fixture.boom", frozenset({"rng", "protect"}), trace=boom)
    findings = verify_targets([t], ENV, rules=[FTV103])
    assert [f.code for f in findings] == ["FTV000"]
    assert "trace exploded" in findings[0].message


def test_every_rule_has_code_name_invariant():
    seen = set()
    for rule in ALL_RULES:
        assert rule.code.startswith("FTV") and rule.name and rule.invariant
        assert rule.code not in seen
        seen.add(rule.code)
    assert len(ALL_RULES) == 6


def test_cli_list_rules_and_unknown_rule():
    from tools.ftverify.core import main
    assert main(["--list-rules"]) == 0
    assert main(["--rules", "FTV999", "--no-baseline"]) == 2


# ---------------------------------------------------------- acceptance gate --
def test_protect_targets_verify_clean():
    """The repo's own protect triplet (reference / fused / per-row) passes
    every trace rule, and every global check (threefry lowering, policy
    registry, cache_shardings) is clean — with the baseline empty."""
    from pathlib import Path

    from tools.ftlint.core import load_baseline
    from tools.ftverify.targets import _protect_targets

    findings = verify_targets(_protect_targets(), ENV)
    assert [f.render() for f in findings] == []
    repo = Path(__file__).resolve().parent.parent
    assert load_baseline(repo / "tools" / "ftverify" / "baseline.txt") == set()
