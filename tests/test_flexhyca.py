import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flexhyca import FTConfig, clean_linear, ft_linear


@pytest.fixture(scope="module")
def xw():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 48))
    w = jax.random.normal(jax.random.PRNGKey(1), (48, 32))
    return x, w


def damage(y, x, w):
    ref = clean_linear(x, w)
    return float(jnp.sqrt(jnp.mean((y - ref) ** 2))
                 / (jnp.sqrt(jnp.mean(ref ** 2)) + 1e-9))


def test_zero_ber_matches_clean(xw):
    x, w = xw
    cfg = FTConfig(ber=0.0, strategy="cl", q_scale=0)
    y = ft_linear(jax.random.PRNGKey(0), x, w, cfg,
                  important=jnp.zeros((32,), bool))
    assert damage(y, x, w) < 1e-6


def test_faults_cause_damage_on_base(xw):
    x, w = xw
    cfg = FTConfig(ber=0.01, strategy="base")
    y = ft_linear(jax.random.PRNGKey(0), x, w, cfg)
    assert damage(y, x, w) > 0.01


def test_crt_protection_monotone(xw):
    x, w = xw
    d = []
    for strat in ("base", "crt1", "crt2", "crt3"):
        cfg = FTConfig(ber=0.01, strategy=strat, weight_faults=False)
        y = ft_linear(jax.random.PRNGKey(5), x, w, cfg)
        d.append(damage(y, x, w))
    assert d[0] > d[1] > d[3]  # more protected bits, less damage


def test_whole_layer_tmr_near_clean(xw):
    x, w = xw
    d_prot, d_unprot = [], []
    for r in range(6):
        key = jax.random.PRNGKey(100 + r)
        cfg = FTConfig(ber=0.005, strategy="arch", weight_faults=False)
        d_prot.append(damage(ft_linear(key, x, w, cfg,
                                       layer_protected=True), x, w))
        # ftlint: disable=FTL001 -- paired run: identical fault stream
        d_unprot.append(damage(ft_linear(key, x, w, cfg,
                                         layer_protected=False), x, w))
    # whole-layer TMR leaves only the 3*ber^2 residual: damage collapses
    assert np.mean(d_prot) < 0.3 * np.mean(d_unprot)


def test_unprotected_layer_in_arch_strategy(xw):
    x, w = xw
    cfg = FTConfig(ber=0.01, strategy="arch", weight_faults=False)
    y = ft_linear(jax.random.PRNGKey(2), x, w, cfg, layer_protected=False)
    assert damage(y, x, w) > 0.01


def test_cl_dppu_protects_important_channels(xw):
    x, w = xw
    imp = jnp.zeros((32,), bool).at[:8].set(True)
    cfg = FTConfig(ber=0.02, strategy="cl", ib_th=8, nb_th=0, q_scale=0,
                   weight_faults=False)
    y = ft_linear(jax.random.PRNGKey(3), x, w, cfg, important=imp)
    ref = clean_linear(x, w, q_scale=0)
    err_imp = float(jnp.abs(y[:, :8] - ref[:, :8]).mean())
    err_ord = float(jnp.abs(y[:, 8:] - ref[:, 8:]).mean())
    assert err_imp < err_ord  # important channels visibly cleaner


def test_cl_better_than_base_same_ber(xw):
    x, w = xw
    imp = jnp.zeros((32,), bool).at[:4].set(True)
    base = ft_linear(jax.random.PRNGKey(4), x, w,
                     FTConfig(ber=0.01, strategy="base"), important=imp)
    cl = ft_linear(jax.random.PRNGKey(4), x, w,
                   FTConfig(ber=0.01, strategy="cl", ib_th=3, nb_th=1),
                   important=imp)
    assert damage(cl, x, w) < damage(base, x, w)
