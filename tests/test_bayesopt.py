import numpy as np

from repro.core import bayesopt as B


def synthetic_eval(cfg):
    """Area grows with protection; accuracy grows with protection."""
    prot = cfg["s_th"] * 4 + cfg["ib_th"] * 0.08 + cfg["nb_th"] * 0.3
    area = prot * (0.5 if cfg["pe_policy"] == "configurable" else 1.0)
    area += cfg["dot_size"] / 512
    acc = min(0.70 + prot * 0.25, 0.78)
    perf = 0.0 if cfg["dot_size"] >= 16 else 0.2
    bw = cfg["s_th"]
    return B.EvalResult(area=area, acc=acc, perf_loss=perf, bw_loss=bw)


def test_dse_finds_feasible_minimum():
    cons = B.Constraints(acc_min=0.75, perf_max=0.10, bw_max=0.10)
    res = B.bayes_design_opt(B.table1_space(), synthetic_eval, cons,
                             iter_max_step=48, seed=0)
    assert res.best is not None
    assert res.best_eval.feasible(cons)
    # sanity: found area not far above the attainable region
    feas = [r.area for c, r in res.history if r.feasible(cons)]
    assert res.best_eval.area == min(feas)


def strict_eval(cfg):
    """Accuracy uncapped and steep: most of the space is infeasible at
    acc_min=0.80, so dominance pruning has real work to do."""
    prot = cfg["s_th"] * 4 + cfg["ib_th"] * 0.08 + cfg["nb_th"] * 0.3
    return B.EvalResult(area=prot, acc=0.70 + prot * 0.08,
                        perf_loss=0.0, bw_loss=0.0)


def test_monotonic_pruning_fires():
    cons = B.Constraints(acc_min=0.80, perf_max=0.5, bw_max=0.5)
    total_pruned = 0
    for seed in range(4):
        res = B.bayes_design_opt(B.table1_space(), strict_eval, cons,
                                 iter_max_step=80, n_init=30,
                                 n_candidates=512, seed=seed)
        total_pruned += res.pruned
    assert total_pruned > 0  # infeasible-dominated configs skipped


def test_constraints_respected():
    cons = B.Constraints(acc_min=0.99)  # unattainable
    res = B.bayes_design_opt(B.table1_space(), synthetic_eval, cons,
                             iter_max_step=24, seed=2)
    assert res.best is None


def test_gp_posterior_sane():
    gp = B._GP()
    X = np.random.default_rng(0).uniform(size=(20, 3))
    y = X.sum(1)
    gp.fit(X, y)
    mu, var = gp.posterior(X[:5])
    assert np.allclose(mu, y[:5], atol=0.2)
    assert (var >= 0).all()
