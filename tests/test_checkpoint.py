import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import checkpoint as C


def state():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "m": {"w": jnp.zeros((3, 4))},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    C.save(d, state(), 7, data_state={"step": 7})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state())
    s, step, ds = C.restore(d, like)
    assert step == 7 and ds == {"step": 7}
    np.testing.assert_array_equal(np.asarray(s["params"]["w"]),
                                  np.arange(12.0).reshape(3, 4))


def test_uncommitted_checkpoint_ignored(tmp_path):
    d = str(tmp_path / "ck")
    C.save(d, state(), 5)
    os.remove(os.path.join(d, "step_5.done"))  # simulate crash mid-commit
    s, step, _ = C.restore(d, state())
    assert s is None and step == -1


def test_latest_wins_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    for i in (1, 2, 3, 4, 5):
        C.save(d, state(), i, keep=3)
    assert C.available_steps(d) == [3, 4, 5]
    _, step, _ = C.restore(d, state())
    assert step == 5


def test_async_save(tmp_path):
    d = str(tmp_path / "ck")
    t = C.save(d, state(), 9, async_write=True)
    t.join()
    assert C.available_steps(d) == [9]


# ---------------------------------------------------------------------------
# Crash safety: a writer killed mid-save must never eat the previous
# committed checkpoint, and an async failure must surface at join().
# ---------------------------------------------------------------------------
def _crashing_savez(monkeypatch):
    def boom(*a, **kw):
        raise IOError("disk died mid-write")
    monkeypatch.setattr(C.np, "savez", boom)


def test_sync_crash_mid_save_keeps_previous(tmp_path, monkeypatch):
    d = str(tmp_path / "ck")
    C.save(d, state(), 1)
    _crashing_savez(monkeypatch)
    import pytest
    with pytest.raises(IOError):
        C.save(d, state(), 2)
    assert C.available_steps(d) == [1]
    s, step, _ = C.restore(d, state())
    assert step == 1 and s is not None


def test_async_crash_raises_at_join_and_keeps_previous(tmp_path, monkeypatch):
    d = str(tmp_path / "ck")
    C.save(d, state(), 3)
    _crashing_savez(monkeypatch)
    w = C.save(d, state(), 4, async_write=True)
    import pytest
    with pytest.raises(IOError):
        w.join()
    assert not w.is_alive()
    assert C.available_steps(d) == [3]
    _, step, _ = C.restore(d, state())
    assert step == 3


def test_gc_never_deletes_newest_committed(tmp_path):
    d = str(tmp_path / "ck")
    for i in (1, 2, 3, 4):
        C.save(d, state(), i, keep=1)
        assert C.available_steps(d) == [i]   # newest always survives pruning


def test_gc_keep_zero_keeps_all(tmp_path):
    d = str(tmp_path / "ck")
    for i in (1, 2, 3, 4, 5):
        C.save(d, state(), i, keep=0)
    assert C.available_steps(d) == [1, 2, 3, 4, 5]
