"""FlexHyCA cost-emulation modes (§Perf hillclimb 3) preserve model math:
the two_pass recompute votes identical values, so outputs must match the
plain path up to dtype noise — the variants differ only in COST."""
import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.models import build
from repro.models.common import EmuCtx, linear


def test_emu_two_pass_is_value_preserving():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    y_plain = linear(x, w)
    y_2p = linear(x, w, ftc=EmuCtx("two_pass", 0.25))
    y_fu = linear(x, w, ftc=EmuCtx("fused", 0.25))
    np.testing.assert_allclose(np.asarray(y_2p), np.asarray(y_plain),
                               rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(y_fu), np.asarray(y_plain))


def test_emu_loss_matches_unprotected():
    cfg = get_config("h2o-danube-1.8b", reduced=True)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab)}
    runs = [RunConfig(param_dtype="float32", compute_dtype="float32",
                      ft_emu=m) for m in ("", "two_pass", "fused")]
    def loss_of(run):
        m = build(cfg, run)
        params = m.init(jax.random.PRNGKey(0))
        loss, _ = jax.jit(lambda p, b: m.loss(p, b))(params, batch)
        return float(loss)

    losses = [loss_of(run) for run in runs]
    assert abs(losses[0] - losses[1]) < 1e-4
    assert abs(losses[0] - losses[2]) < 1e-6
