"""ftlint rule-engine tests: one positive (fires), one negative (stays
quiet), and suppression coverage per rule, plus the acceptance gate —
the repo itself lints clean with an empty baseline.

Fixtures live in string literals so this file itself stays clean under
``python -m tools.ftlint tests``.
"""
import textwrap
from pathlib import Path

from tools.ftlint import ALL_RULES, lint_paths, lint_source
from tools.ftlint.core import load_baseline, split_baselined

REPO = Path(__file__).resolve().parent.parent


def codes(src, path="pkg/mod.py"):
    return [f.code for f in lint_source(textwrap.dedent(src), path)]


# ------------------------------------------------------------------ FTL001 --
def test_ftl001_positive_key_reused():
    src = """
    import jax

    def draw(key):
        a = jax.random.normal(key, (4,))
        b = jax.random.normal(key, (4,))
        return a + b
    """
    assert codes(src) == ["FTL001"]


def test_ftl001_negative_split_keys():
    src = """
    import jax

    def draw(key):
        k1, k2 = jax.random.split(key)
        a = jax.random.normal(k1, (4,))
        b = jax.random.normal(k2, (4,))
        return a + b
    """
    assert codes(src) == []


def test_ftl001_positive_loop_replay():
    src = """
    import jax

    def draws(key, n):
        out = []
        for i in range(n):
            out.append(jax.random.normal(key, (2,)))
        return out
    """
    assert codes(src) == ["FTL001"]


def test_ftl001_negative_loop_fold_in():
    src = """
    import jax

    def draws(key, n):
        out = []
        for i in range(n):
            k = jax.random.fold_in(key, i)
            out.append(jax.random.normal(k, (2,)))
        return out
    """
    assert codes(src) == []


def test_ftl001_suppressed_with_justification():
    src = """
    import jax

    def paired(key, x):
        a = jax.random.bernoulli(key, 0.5, x.shape)
        # ftlint: disable=FTL001 -- paired draw: same stream by design
        b = jax.random.bernoulli(key, 0.5, x.shape)
        return a, b
    """
    assert codes(src) == []


def test_ftl001_suppression_without_justification_is_ftl000():
    src = """
    import jax

    def paired(key, x):
        a = jax.random.bernoulli(key, 0.5, x.shape)
        b = jax.random.bernoulli(key, 0.5, x.shape)  # ftlint: disable=FTL001
        return a, b
    """
    assert codes(src) == ["FTL000"]


# ------------------------------------------------------------------ FTL002 --
def test_ftl002_positive_host_random_under_jit():
    src = """
    import random

    import jax

    @jax.jit
    def f(x):
        return x * random.random()
    """
    assert codes(src) == ["FTL002"]


def test_ftl002_positive_item_in_scan_body():
    src = """
    import jax

    def step(c, x):
        return c + x.item(), None

    def run(xs):
        return jax.lax.scan(step, 0.0, xs)
    """
    assert codes(src) == ["FTL002"]


def test_ftl002_negative_host_random_outside_trace():
    src = """
    import random

    def pick(xs):
        return random.choice(xs)
    """
    assert codes(src) == []


def test_ftl002_positive_set_iteration_in_traced_code():
    src = """
    import jax

    @jax.jit
    def f(x):
        for name in {"a", "b"}:
            x = x + len(name)
        return x
    """
    assert codes(src) == ["FTL002"]


# ------------------------------------------------------------------ FTL003 --
def test_ftl003_positive_structural_data_leaf():
    src = """
    import jax

    jax.tree_util.register_dataclass(MyPolicy,
                                     data_fields=["ber", "s_th"],
                                     meta_fields=["name"])
    """
    assert codes(src) == ["FTL003"]


def test_ftl003_negative_ber_only_leaf():
    src = """
    import jax

    jax.tree_util.register_dataclass(MyPolicy, data_fields=["ber"],
                                     meta_fields=["s_th", "name"])
    """
    assert codes(src) == []


def test_ftl003_positive_frozen_mutation_outside_ft():
    src = """
    def hack(policy):
        object.__setattr__(policy, "ber", 0.1)
    """
    assert codes(src, "src/repro/serve/engine.py") == ["FTL003"]


def test_ftl003_negative_frozen_mutation_inside_ft():
    src = """
    def __post_init__(self):
        object.__setattr__(self, "ber", float(self.ber))
    """
    assert codes(src, "src/repro/ft/policy.py") == []


def test_ftl003_positive_policy_built_in_traced_code():
    src = """
    import jax

    from repro.ft import get_policy

    @jax.jit
    def f(x):
        pol = get_policy("cl")
        return x * pol.ber
    """
    assert codes(src) == ["FTL003"]


# ------------------------------------------------------------------ FTL004 --
def test_ftl004_positive_float_cast_and_unpinned_matmul():
    src = """
    import jax.numpy as jnp

    def accumulate(xq, wq):
        y = jnp.matmul(xq, wq)
        return y.astype(jnp.float32)
    """
    got = codes(src, "src/repro/kernels/qmatmul/ref.py")
    assert got == ["FTL004", "FTL004"]


def test_ftl004_negative_pinned_matmul_and_scale_boundary():
    src = """
    import jax.numpy as jnp

    def accumulate(xq, wq, scale):
        y = jnp.matmul(xq, wq, preferred_element_type=jnp.int32)
        return y.astype(jnp.float32) * scale
    """
    assert codes(src, "src/repro/kernels/qmatmul/ref.py") == []


def test_ftl004_negative_outside_datapath_files():
    src = """
    import jax.numpy as jnp

    def accumulate(xq, wq):
        y = jnp.matmul(xq, wq)
        return y.astype(jnp.float32)
    """
    assert codes(src, "src/repro/models/attention.py") == []


# ------------------------------------------------------------------ FTL005 --
def test_ftl005_positive_bare_pallas_call():
    src = """
    from jax.experimental import pallas as pl

    def run(kernel, x):
        return pl.pallas_call(kernel, out_shape=x)(x)
    """
    got = codes(src, "src/repro/kernels/newkern/kernel.py")
    # missing interpret=, missing compiler_params, no divisibility guard
    assert got == ["FTL005", "FTL005", "FTL005"]


def test_ftl005_negative_full_kernel_contract():
    src = """
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    def run(kernel, x, bm, interpret=False):
        assert x.shape[0] % bm == 0
        return pl.pallas_call(
            kernel,
            out_shape=x,
            interpret=interpret,
            compiler_params=pltpu.TPUCompilerParams(
                dimension_semantics=("parallel",)),
            scratch_shapes=[pltpu.VMEM((8, 128), jnp.float32)],
        )(x)
    """
    assert codes(src, "src/repro/kernels/newkern/kernel.py") == []


def test_ftl005_positive_hardcoded_interpret():
    src = """
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    def run(kernel, x, bm):
        assert x.shape[0] % bm == 0
        return pl.pallas_call(
            kernel, out_shape=x, interpret=True,
            compiler_params=pltpu.TPUCompilerParams(
                dimension_semantics=("parallel",)),
        )(x)
    """
    assert codes(src, "src/repro/kernels/newkern/kernel.py") == ["FTL005"]


# ------------------------------------------------------------------ FTL006 --
def test_ftl006_positive_policy_marked_static():
    src = """
    from functools import partial

    import jax

    @partial(jax.jit, static_argnames=("policy",))
    def f(x, policy):
        return x
    """
    assert codes(src) == ["FTL006"]


def test_ftl006_positive_unhashable_static_default():
    src = """
    from functools import partial

    import jax

    @partial(jax.jit, static_argnums=(1,))
    def f(x, dims=[1, 2]):
        return x
    """
    assert codes(src) == ["FTL006"]


def test_ftl006_positive_jit_in_loop_and_bound_method():
    src = """
    import jax

    def run(model, xs):
        out = []
        for x in xs:
            out.append(jax.jit(model.forward)(x))
        return out
    """
    got = codes(src)
    assert got == ["FTL006", "FTL006"]  # bound method + jit-per-iteration


def test_ftl006_negative_hashable_static_args():
    src = """
    from functools import partial

    import jax

    @partial(jax.jit, static_argnames=("n", "treedef"))
    def f(x, n, treedef):
        return x * n
    """
    assert codes(src) == []


# ------------------------------------------------------------------ FTL007 --
def test_ftl007_positive_config_update_in_library_code():
    src = """
    import jax

    jax.config.update("jax_enable_x64", True)
    """
    assert codes(src, "src/repro/serve/engine.py") == ["FTL007"]


def test_ftl007_positive_through_import_alias():
    src = """
    from jax import config

    config.update("jax_default_matmul_precision", "float32")
    """
    assert codes(src, "src/repro/models/common.py") == ["FTL007"]


def test_ftl007_negative_sanctioned_site_and_tests():
    src = """
    import jax

    jax.config.update("jax_threefry_partitionable", True)
    """
    assert codes(src, "src/repro/core/faults.py") == []
    assert codes(src, "tests/test_faults.py") == []
    assert codes(src, "tests/conftest.py") == []


# --------------------------------------------------------------- machinery --
def test_syntax_error_is_ftl000_not_crash():
    assert codes("def broken(:\n    pass") == ["FTL000"]


def test_multi_code_suppression_covers_each_listed_code():
    src = """
    import jax

    def draw(key):
        a = jax.random.normal(key, (4,))
        b = jax.random.normal(key, (4,))  # ftlint: disable=FTL001,FTL004 -- paired by design
        return a + b
    """
    assert codes(src) == []


def test_empty_justification_marker_does_not_suppress():
    """A trailing ``--`` with no reason is not a valid waiver: the marker
    fails to parse and the original finding stays visible (fail-closed)."""
    src = """
    import jax

    def paired(key, x):
        a = jax.random.bernoulli(key, 0.5, x.shape)
        b = jax.random.bernoulli(key, 0.5, x.shape)  # ftlint: disable=FTL001 --
        return a, b
    """
    assert codes(src) == ["FTL001"]


def test_missing_file_warns_not_crashes(tmp_path, capsys):
    from tools.ftlint.core import iter_py_files, lint_paths
    assert list(iter_py_files(["no_such_file.py"], tmp_path)) == []
    assert lint_paths(["no_such_file.py"], root=tmp_path) == []
    assert "no such file" in capsys.readouterr().err


def test_deleted_file_mid_run_warns_not_crashes(tmp_path, capsys):
    from tools.ftlint.core import lint_file
    ghost = tmp_path / "ghost.py"
    assert lint_file(ghost, tmp_path) == []
    assert "cannot read" in capsys.readouterr().err


def test_baseline_entry_for_deleted_file_is_stale_not_fatal(tmp_path, capsys):
    """A baseline line pointing at a file that no longer exists must not
    fail the run — it surfaces as a stale-entry note."""
    from tools.ftlint.core import main
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    bl = tmp_path / "baseline.txt"
    bl.write_text("FTL001 src/gone/forever.py::draw::key reused\n")
    assert main([str(clean), "--baseline", str(bl)]) == 0
    assert "stale baseline" in capsys.readouterr().err


def test_report_key_matches_baseline_roundtrip(tmp_path):
    """The JSON report's ``key`` field is the exact baseline key: pasting a
    reported key into baseline.txt must suppress that finding on the next
    run (the report used to omit the key, and consumers reconstructing it
    drifted from the baseline format)."""
    import json

    from tools.ftlint.core import main
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent("""
        import jax

        def draw(key):
            a = jax.random.normal(key, (4,))
            b = jax.random.normal(key, (4,))
            return a + b
    """))
    empty = tmp_path / "empty_baseline.txt"
    empty.write_text("")
    report = tmp_path / "report.json"
    assert main([str(bad), "--baseline", str(empty),
                 "--write-report", str(report)]) == 1
    rows = json.loads(report.read_text())["new"]
    assert rows and all("key" in r for r in rows)
    bl = tmp_path / "baseline.txt"
    bl.write_text("\n".join(r["key"] for r in rows) + "\n")
    assert main([str(bad), "--baseline", str(bl)]) == 0


def test_baseline_split_roundtrip():
    src = """
    import jax

    def draw(key):
        a = jax.random.normal(key, (4,))
        b = jax.random.normal(key, (4,))
        return a + b
    """
    findings = lint_source(textwrap.dedent(src), "pkg/mod.py")
    new, old = split_baselined(findings,
                               {f.baseline_key() for f in findings})
    assert new == [] and old == findings


def test_every_rule_has_code_name_invariant():
    seen = set()
    for rule in ALL_RULES:
        assert rule.code.startswith("FTL") and rule.name and rule.invariant
        assert rule.code not in seen
        seen.add(rule.code)
    assert len(ALL_RULES) >= 6


# ---------------------------------------------------------- acceptance gate --
def test_repo_lints_clean_with_empty_baseline():
    """The whole repo passes every rule; the baseline stays empty (any
    future entry needs a justification in the PR that adds it)."""
    findings = lint_paths(["src", "tests", "benchmarks", "examples",
                           "tools"],
                          root=REPO)
    assert [f.render() for f in findings] == []
    assert load_baseline(REPO / "tools" / "ftlint" / "baseline.txt") == set()
