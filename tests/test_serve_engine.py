"""Scan-fused serving engine: parity, key hygiene, dispatch accounting.

The fused ``lax.scan`` decode loop must be a pure optimization: at
temperature 0 it emits bit-identical tokens to the legacy per-token python
loop under *every* registry protection policy and both ft backends — the
whole point of serving the paper's protected datapath fast is that the
protection semantics don't move.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ft
from repro.configs import get_config
from repro.models import build
from repro.serve.engine import Engine, ServeConfig


@pytest.fixture(scope="module")
def danube():
    cfg = get_config("h2o-danube-1.8b", reduced=True)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 9),
                                          0, cfg.vocab)}
    return m, params, batch


def _policy(name, **kw):
    # weight_faults=False keeps the parity sweep's compile cost sane (the
    # weight-SRAM fault planes double every site's injection graph and are
    # schedule-independent); test_per_call_keys_fresh_faults covers the
    # weight-fault stream with the default weight_faults=True
    return ft.get_policy(name, ber=1e-3, weight_faults=False, **kw)


def _pair(m, params, n_new=6, policy=None, **kw):
    scan = Engine(m, params, cfg=ServeConfig(max_new_tokens=n_new),
                  policy=policy, **kw)
    py = Engine(m, params, cfg=ServeConfig(max_new_tokens=n_new),
                policy=policy, loop="python", **kw)
    return scan, py


@pytest.mark.parametrize("name", [None, *ft.list_policies()])
def test_scan_matches_python_under_every_policy(danube, name):
    m, params, batch = danube
    policy = None if name is None else _policy(name)
    scan, py = _pair(m, params, n_new=4, policy=policy)
    a = np.asarray(scan.generate(batch, seed=3))
    b = np.asarray(py.generate(batch, seed=3))
    np.testing.assert_array_equal(a, b)
    assert a.shape == (2, 4)


def test_scan_matches_python_pallas_backend(danube):
    m, params, batch = danube
    policy = _policy("crt3")
    scan, py = _pair(m, params, n_new=4, policy=policy, ft_backend="pallas",
                     ft_t=6, ft_interpret=True)
    a = np.asarray(scan.generate(batch, seed=3))
    b = np.asarray(py.generate(batch, seed=3))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("arch", ["qwen3-moe-235b-a22b", "paligemma-3b",
                                  "mamba2-2.7b"])
def test_scan_matches_python_across_families(arch):
    cfg = get_config(arch, reduced=True)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 7),
                                          0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (2, cfg.n_frontend_tokens, cfg.d_model),
            jnp.bfloat16)
    policy = _policy("crt2")
    scan, py = _pair(m, params, n_new=4, policy=policy)
    np.testing.assert_array_equal(np.asarray(scan.generate(batch, seed=1)),
                                  np.asarray(py.generate(batch, seed=1)))


def test_roundtrip_accounting(danube):
    m, params, batch = danube
    scan, py = _pair(m, params, n_new=8)
    scan.generate(batch)
    py.generate(batch)
    assert scan.stats.roundtrips == 2          # prefill + fused loop
    assert py.stats.roundtrips == 1 + 8        # prefill + one per token
    assert py.stats.roundtrips / scan.stats.roundtrips >= 4.5


def test_per_call_keys_fresh_faults(danube):
    """Back-to-back generate() calls must not replay the same fault draws
    (the seed engine reused cfg.seed-derived keys on every call)."""
    m, params, batch = danube
    eng = Engine(m, params, cfg=ServeConfig(max_new_tokens=8),
                 policy=ft.get_policy("base", ber=3e-3))
    a = np.asarray(eng.generate(batch))
    b = np.asarray(eng.generate(batch))
    assert not (a == b).all()                  # fresh fault pattern
    # pinned streams replay exactly, for reliability accounting
    c = np.asarray(eng.generate(batch, seed=11))
    d = np.asarray(eng.generate(batch, seed=11))
    np.testing.assert_array_equal(c, d)
    k = jax.random.PRNGKey(4)
    np.testing.assert_array_equal(np.asarray(eng.generate(batch, key=k)),
                                  np.asarray(eng.generate(batch, key=k)))
    with pytest.raises(ValueError):
        eng.generate(batch, key=k, seed=1)


def test_temperature_sampling_parity_and_freshness(danube):
    """At temperature > 0 the scan path threads the sampling key through the
    carry with the same fold schedule as the python loop."""
    m, params, batch = danube
    scan_t = Engine(m, params, cfg=ServeConfig(max_new_tokens=8,
                                               temperature=1.0))
    py_t = Engine(m, params, cfg=ServeConfig(max_new_tokens=8,
                                             temperature=1.0),
                  loop="python")
    a = np.asarray(scan_t.generate(batch, seed=5))
    b = np.asarray(py_t.generate(batch, seed=5))
    np.testing.assert_array_equal(a, b)
    assert not (a == np.asarray(scan_t.generate(batch, seed=6))).all()


def test_engine_rejects_unknown_loop(danube):
    m, params, _ = danube
    with pytest.raises(ValueError):
        Engine(m, params, loop="unrolled")


def test_zero_new_tokens_is_prefill_only(danube):
    m, params, batch = danube
    eng = Engine(m, params, cfg=ServeConfig(max_new_tokens=8))
    out = eng.generate(batch, max_new_tokens=0)
    assert out.shape == (2, 0)
    assert eng.stats.roundtrips == 1
