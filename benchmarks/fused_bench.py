"""fused_vs_composed — the fused inject->protect->qmatmul decode kernel
against the composed three-dispatch pipeline.

Two views, matching how the claim is actually checked:

  * **analytic roofline** (``roofline.fused_decode_bytes``): HBM bytes and
    arithmetic intensity per protected decode-step linear at real decode
    shapes.  Decode is memory-bound, so the bytes ratio is the expected
    step-time ratio on hardware; the fused kernel's win comes from packed
    int32 flip words (4 B/elem vs 8 uint32 planes = 32 B/elem), reading
    activations/weights once, and keeping every intermediate in VMEM.
  * **measured serving throughput**: ``serve.Engine`` tokens/sec with
    ``ft_backend="reference"`` vs ``ft_backend="fused"`` on the reduced
    config, plus a temperature-0 token-parity check (the fused backend must
    be a pure optimization).  On CPU the Pallas kernel runs in *interpret
    mode* — a correctness oracle, not a speed proxy — so tokens/sec here
    validates plumbing overhead, while the analytic table carries the
    hardware claim.

``python -m benchmarks.fused_bench --snapshot`` writes the committed
``BENCH_fused_decode.json`` (case, tok/s, bytes/step) — see docs/kernels.md
for the snapshot convention.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import roofline as R
from repro import ft
from repro.configs import get_config
from repro.models import build
from repro.serve.engine import Engine, ServeConfig

BATCH = 2
PROMPT = 8
NEW = 12
REPS = 2
POLICY = "crt3"


def _time_engine(model, params, policy, backend, batch):
    eng = Engine(model, params, cfg=ServeConfig(max_new_tokens=NEW),
                 policy=policy, ft_backend=backend)
    toks = eng.generate(batch, seed=0)
    jax.block_until_ready(toks)                            # compile
    t0 = time.perf_counter()
    for r in range(REPS):
        jax.block_until_ready(eng.generate(batch, seed=0))
    dt = time.perf_counter() - t0
    return (REPS * eng.stats.tokens) / dt, [int(t) for t in
                                            jnp.ravel(toks)]


def fused_vs_composed():
    rows = [dict(case=f"analytic_M{r['M']}_K{r['K']}_N{r['N']}", **r)
            for r in R.fused_decode_table()]
    cfg = get_config("h2o-danube-1.8b", reduced=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (BATCH, PROMPT), 0, cfg.vocab)}
    policy = ft.get_policy(POLICY, ber=1e-3, weight_faults=False)
    tps_ref, toks_ref = _time_engine(model, params, policy, "reference",
                                     batch)
    tps_fus, toks_fus = _time_engine(model, params, policy, "fused", batch)
    rows.append(dict(case="engine_tok_s", policy=POLICY,
                     reference_tok_s=round(tps_ref, 1),
                     fused_interpret_tok_s=round(tps_fus, 1),
                     tokens_match=toks_ref == toks_fus))
    analytic = [r for r in rows if r["case"].startswith("analytic")]
    derived = dict(
        min_bytes_ratio=min(r["bytes_ratio"] for r in analytic),
        min_ai_uplift=min(r["ai_uplift"] for r in analytic),
        tokens_match=toks_ref == toks_fus)
    assert toks_ref == toks_fus, "fused backend diverged from reference"
    return rows, derived


def snapshot(path="BENCH_fused_decode.json"):
    """Commit-able --fast snapshot: one row per case with tok/s (measured,
    interpret-mode) and HBM bytes/step (analytic)."""
    import json
    rows, derived = fused_vs_composed()
    snap = []
    for r in rows:
        if r["case"].startswith("analytic"):
            snap.append(dict(case=r["case"],
                             composed_bytes_per_step=r["composed_bytes"],
                             fused_bytes_per_step=r["fused_bytes"],
                             bytes_ratio=r["bytes_ratio"],
                             fused_ai=r["fused_ai"],
                             composed_ai=r["composed_ai"]))
        else:
            snap.append(dict(case=r["case"],
                             reference_tok_s=r["reference_tok_s"],
                             fused_interpret_tok_s=r["fused_interpret_tok_s"],
                             tokens_match=r["tokens_match"]))
    with open(path, "w") as f:
        json.dump(dict(suite="fused_vs_composed", rows=snap,
                       derived=derived), f, indent=1)
        f.write("\n")
    return path


if __name__ == "__main__":
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--snapshot", action="store_true")
    args = ap.parse_args()
    if args.snapshot:
        print(f"# wrote {snapshot()}")
    else:
        rows, derived = fused_vs_composed()
        for r in rows:
            print(r)
        print(json.dumps(derived))
