"""Benchmark harness: one entry per paper table/figure + the roofline table.
Prints ``name,us_per_call,derived`` CSV and writes benchmarks/results.json.

Usage: PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "results.json")


def _benchmarks():
    from benchmarks import paper_figs as F
    from benchmarks import roofline as R
    from benchmarks.dse_batch import dse_batched_vs_sequential
    from benchmarks.fused_bench import fused_vs_composed
    from benchmarks.serve_bench import serve_scaling, serve_scan_vs_python
    from benchmarks.train_bench import fat_dse, fat_vs_baseline

    def roofline_single():
        rows = R.full_table("single")
        return rows, R.summarize(rows)

    def roofline_multi():
        rows = R.full_table("multi")
        return rows, R.summarize(rows)

    return {
        "fig5_layer_sensitivity": F.fig5_layer_sensitivity,
        "fig6_cumulative_protection": F.fig6_cumulative_protection,
        "fig7_strategy_accuracy": F.fig7_strategy_accuracy,
        "fig8_strategy_perf": F.fig8_strategy_perf,
        "fig9_strategy_area": F.fig9_strategy_area,
        "fig10_neuron_bits": F.fig10_neuron_bits,
        "fig11_qscale": F.fig11_qscale,
        "fig12_dppu_area": F.fig12_dppu_area,
        "fig13_io_overhead": F.fig13_io_overhead,
        "fig14_bit_area": F.fig14_bit_area,
        "fig15_table2_dse": F.fig15_table2_dse,
        "dse_batched_vs_sequential": dse_batched_vs_sequential,
        "fused_vs_composed": fused_vs_composed,
        "serve_scan_vs_python": serve_scan_vs_python,
        "serve_scaling": serve_scaling,
        "fat_vs_baseline": fat_vs_baseline,
        "fat_dse": fat_dse,
        "roofline_single_pod": roofline_single,
        "roofline_multi_pod": roofline_multi,
    }


# DSE entries rerun fault injection many times; the batched-vs-sequential
# comparison deliberately includes a slow sequential arm.  serve_scaling
# spawns one fresh-compile subprocess per (config, policy, device-count) arm.
FAST_SKIP = {"fig15_table2_dse", "dse_batched_vs_sequential", "fat_dse",
             "serve_scaling"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true")
    args, _ = ap.parse_known_args()

    benches = _benchmarks()
    if args.only:
        benches = {k: v for k, v in benches.items() if args.only in k}
    out = {}
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if args.fast and name in FAST_SKIP:
            continue
        import jax
        jax.clear_caches()  # each fig compiles many distinct FT configs
        t0 = time.time()
        rows, derived = fn()
        dt_us = (time.time() - t0) * 1e6
        out[name] = {"rows": rows, "derived": derived,
                     "seconds": round(dt_us / 1e6, 2)}
        d = derived if not isinstance(derived, dict) else json.dumps(derived)
        print(f"{name},{dt_us:.0f},{d}", flush=True)
    if os.path.exists(RESULTS_PATH):  # merge with prior (--only reruns)
        prior = json.load(open(RESULTS_PATH))
        prior.update(out)
        out = prior
    with open(RESULTS_PATH, "w") as f:
        json.dump(out, f, indent=1, default=str)
    print(f"# wrote {RESULTS_PATH}")


if __name__ == "__main__":
    main()
