"""The paper's benchmark DLA workloads: VGG16 and ResNet50 at 224x224 as
im2col GEMM sequences (exact layer dimensions), for the analytic perf/IO
models (Figs. 8, 13)."""
from __future__ import annotations

from repro.core.perfmodel import Gemm

# (name, out_hw, k, cin, cout) — VGG16 convs + fc
_VGG16 = [
    ("conv1_1", 224, 3, 3, 64), ("conv1_2", 224, 3, 64, 64),
    ("conv2_1", 112, 3, 64, 128), ("conv2_2", 112, 3, 128, 128),
    ("conv3_1", 56, 3, 128, 256), ("conv3_2", 56, 3, 256, 256),
    ("conv3_3", 56, 3, 256, 256),
    ("conv4_1", 28, 3, 256, 512), ("conv4_2", 28, 3, 512, 512),
    ("conv4_3", 28, 3, 512, 512),
    ("conv5_1", 14, 3, 512, 512), ("conv5_2", 14, 3, 512, 512),
    ("conv5_3", 14, 3, 512, 512),
]

# ResNet50: (name, out_hw, k, cin, cout, repeats)
_RESNET50 = [
    ("conv1", 112, 7, 3, 64, 1),
    ("c2_a", 56, 1, 64, 64, 3), ("c2_b", 56, 3, 64, 64, 3),
    ("c2_c", 56, 1, 64, 256, 3),
    ("c3_a", 28, 1, 256, 128, 4), ("c3_b", 28, 3, 128, 128, 4),
    ("c3_c", 28, 1, 128, 512, 4),
    ("c4_a", 14, 1, 512, 256, 6), ("c4_b", 14, 3, 256, 256, 6),
    ("c4_c", 14, 1, 256, 1024, 6),
    ("c5_a", 7, 1, 1024, 512, 3), ("c5_b", 7, 3, 512, 512, 3),
    ("c5_c", 7, 1, 512, 2048, 3),
]


def _sens_rank(gemms):
    """Early layers are the fault-sensitive set (cf. Fig. 5): mark the first
    ~40% as sensitive."""
    n = int(0.4 * len(gemms))
    return [Gemm(g.name, g.M, g.K, g.N, sensitive=(i < n))
            for i, g in enumerate(gemms)]


def vgg16_gemms() -> list[Gemm]:
    out = [Gemm(n, hw * hw, k * k * cin, cout)
           for n, hw, k, cin, cout in _VGG16]
    out.append(Gemm("fc6", 1, 7 * 7 * 512, 4096))
    out.append(Gemm("fc7", 1, 4096, 4096))
    out.append(Gemm("fc8", 1, 4096, 1000))
    return _sens_rank(out)


def resnet50_gemms() -> list[Gemm]:
    out = []
    for n, hw, k, cin, cout, rep in _RESNET50:
        for r in range(rep):
            out.append(Gemm(f"{n}.{r}", hw * hw, k * k * cin, cout))
    out.append(Gemm("fc", 1, 2048, 1000))
    return _sens_rank(out)
