"""serve_scan_vs_python / serve_scaling — serving-path throughput.

Measures the three serving paths on the reduced configs of three workload
families (dense LM, MoE, vision-frontend VLM), clean and under a registry
protection policy:

  * ``python`` — the legacy per-token dispatch loop (1 jit call per token),
  * ``scan``   — the fused ``lax.scan`` decode loop (1 jit call per
    generation; fault keys folded inside the scan),
  * ``sched``  — the continuous-batching scheduler on top of the fused
    chunked loop (per-request fault streams, bucketed prefill).

Reports tokens/sec (steady-state: compile excluded by a warmup call) and
host roundtrips (jitted executable invocations) per generation.  The scan
path must cut roundtrips by >=5x vs the python loop at equal (bit-identical
at temperature 0) outputs — that equality is enforced by
tests/test_serve_engine.py; this benchmark measures the speed side.

``serve_scaling`` measures sharded-serving throughput 1 -> N devices
(dense vs MoE, clean vs crt3).  Each arm runs in a subprocess under
``--xla_force_host_platform_device_count=N`` with a pure-DP (N, 1) mesh and
a batch that grows with the device count — **weak scaling**: on the
host-platform backend all N "devices" share the same cores, so per-device
work is held constant and throughput rises as the batch amortizes the
fixed per-step dispatch overhead.  On real accelerators the same harness
measures strong scaling; the snapshot's meta block records which regime
produced it.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp

from repro import ft
from repro.configs import get_config
from repro.models import build
from repro.serve.engine import Engine, ServeConfig
from repro.serve.scheduler import Request, Scheduler, SchedulerConfig

CONFIGS = (
    ("dense", "h2o-danube-1.8b"),
    ("moe", "qwen3-moe-235b-a22b"),
    ("vision", "paligemma-3b"),
)
POLICIES = (None, "crt3")
BATCH = 2
PROMPT = 8
NEW = 16
REPS = 2


def _policy(name):
    if name is None:
        return None
    # weight_faults=False: the per-request scheduler arm requires it (shared
    # ECC weight SRAM), and the arms must serve the same design
    return ft.get_policy(name, ber=1e-3, weight_faults=False)


def _batch_for(cfg, key):
    batch = {"tokens": jax.random.randint(key, (BATCH, PROMPT), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (BATCH, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    return batch


def _time_engine(model, params, policy, loop, batch):
    eng = Engine(model, params, cfg=ServeConfig(max_new_tokens=NEW),
                 policy=policy, loop=loop)
    jax.block_until_ready(eng.generate(batch, seed=0))     # compile
    t0 = time.perf_counter()
    for r in range(REPS):
        jax.block_until_ready(eng.generate(batch, seed=r))
    dt = time.perf_counter() - t0
    return (REPS * eng.stats.tokens) / dt, eng.stats.roundtrips


def _time_sched(model, params, policy, cfg):
    front = (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)

    def reqs():
        out = []
        for i in range(2 * BATCH):
            key = jax.random.PRNGKey(100 + i)
            toks = [int(t) for t in jax.random.randint(
                key, (PROMPT - (i % 3),), 0, cfg.vocab)]
            extras = None
            if cfg.frontend == "vision":
                extras = {"patch_embeds": jax.random.normal(
                    jax.random.fold_in(key, 1),
                    (front, cfg.d_model), jnp.bfloat16)}
            out.append(Request(rid=i, tokens=toks, max_new_tokens=NEW,
                               extras=extras))
        return out

    sched = Scheduler(model, params,
                      SchedulerConfig(max_batch=BATCH, buckets=(PROMPT,),
                                      max_new_tokens=NEW, decode_chunk=8),
                      policy=policy)
    sched.run(reqs())                                      # compile
    t0 = time.perf_counter()
    done = sched.run(reqs())
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.generated) for r in done.values())
    return n_tok / dt, sched.stats.roundtrips


def serve_scan_vs_python():
    rows = []
    ratios, uplifts = [], []
    for fam, arch in CONFIGS:
        cfg = get_config(arch, reduced=True)
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch_for(cfg, jax.random.PRNGKey(1))
        for pname in POLICIES:
            pol = _policy(pname)
            tps_py, rt_py = _time_engine(model, params, pol, "python", batch)
            tps_sc, rt_sc = _time_engine(model, params, pol, "scan", batch)
            tps_sd, rt_sd = _time_sched(model, params, pol, cfg)
            ratios.append(rt_py / rt_sc)
            uplifts.append(tps_sc / tps_py)
            rows.append(dict(
                family=fam, policy=pname or "clean",
                python_tok_s=round(tps_py, 1), scan_tok_s=round(tps_sc, 1),
                sched_tok_s=round(tps_sd, 1),
                python_roundtrips=rt_py, scan_roundtrips=rt_sc,
                sched_roundtrips=rt_sd,
                roundtrip_ratio=round(rt_py / rt_sc, 1),
                tok_s_uplift=round(tps_sc / tps_py, 2)))
    derived = dict(
        min_roundtrip_ratio=round(min(ratios), 1),
        min_tok_s_uplift=round(min(uplifts), 2),
        geomean_tok_s_uplift=round(
            float(jnp.exp(jnp.mean(jnp.log(jnp.asarray(uplifts))))), 2))
    return rows, derived


# ------------------------------------------------------- serve_scaling ----
SCALE_DEVICES = (1, 2, 4)
SCALE_CONFIGS = (("dense", "h2o-danube-1.8b"), ("moe", "qwen3-moe-235b-a22b"))
SCALE_BASE_BATCH = 4
SCALE_REPS = 3

_SCALE_WORKER = """
    import dataclasses, json, time
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro import ft
    from repro.configs import get_config
    from repro.models import build
    from repro.serve.engine import Engine, ServeConfig

    arch, pname, devices = {arch!r}, {policy!r}, {devices}
    cfg = get_config(arch, reduced=True)
    if cfg.moe is not None:
        # capacity is per-shard: give headroom so no partitioning drops
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = Mesh(np.array(jax.devices()).reshape(devices, 1),
                ("data", "model"))
    B = {base_batch} * devices                     # weak scaling
    batch = {{"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                           (B, {prompt}), 0, cfg.vocab)}}
    policy = (None if pname is None
              else ft.get_policy(pname, ber=1e-3, weight_faults=False))
    eng = Engine(model, params, mesh=mesh,
                 cfg=ServeConfig(max_new_tokens={new}), policy=policy)
    jax.block_until_ready(eng.generate(batch, seed=0))      # compile
    rates = []
    for r in range({reps}):
        t0 = time.perf_counter()
        jax.block_until_ready(eng.generate(batch, seed=r))
        rates.append(eng.stats.tokens / (time.perf_counter() - t0))
    print(json.dumps({{"tok_s": sorted(rates)[len(rates) // 2]}}))
"""


def _scale_worker(arch, policy, devices):
    env = dict(os.environ)
    # same env the determinism battery documents for sharded serving, so the
    # measured executable is the one whose outputs the tests pin down
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        "--xla_allow_excess_precision=false")
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent(_SCALE_WORKER.format(
        arch=arch, policy=policy, devices=devices,
        base_batch=SCALE_BASE_BATCH, prompt=PROMPT, new=NEW,
        reps=SCALE_REPS))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1800, env=env)
    if out.returncode != 0:
        raise RuntimeError(f"serve_scaling worker {arch}/{policy}/"
                           f"{devices}dev failed:\n{out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])["tok_s"]


def serve_scaling():
    """Tokens/sec 1 -> N devices for the sharded Engine (weak scaling on the
    host-platform backend; see module docstring)."""
    rows = []
    derived = {}
    for fam, arch in SCALE_CONFIGS:
        for pname in POLICIES:
            tps = [_scale_worker(arch, pname, d) for d in SCALE_DEVICES]
            label = f"{fam}_{pname or 'clean'}"
            for d, t in zip(SCALE_DEVICES, tps):
                rows.append(dict(family=fam, policy=pname or "clean",
                                 devices=d,
                                 batch=SCALE_BASE_BATCH * d,
                                 tok_s=round(t, 1)))
            derived[f"{label}_monotonic"] = bool(
                all(b > a for a, b in zip(tps, tps[1:])))
            derived[f"{label}_scaling_{SCALE_DEVICES[-1]}x"] = round(
                tps[-1] / tps[0], 2)
    return rows, derived


def scaling_snapshot(path="BENCH_serve_scaling.json"):
    """Commit-able snapshot of the serve_scaling sweep."""
    rows, derived = serve_scaling()
    meta = dict(
        regime="weak",
        note="host-platform devices share one CPU: batch grows with the "
             "device count, so throughput rises by amortizing fixed "
             "per-step dispatch overhead; on real accelerators the same "
             "harness measures strong scaling",
        devices=list(SCALE_DEVICES), base_batch=SCALE_BASE_BATCH,
        prompt=PROMPT, new_tokens=NEW, mesh="(devices, 1) = (data, model)")
    with open(path, "w") as f:
        json.dump(dict(suite="serve_scaling", meta=meta, rows=rows,
                       derived=derived), f, indent=1)
        f.write("\n")
    return path


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scaling", action="store_true",
                    help="run serve_scaling and write BENCH_serve_scaling.json")
    args = ap.parse_args()
    if args.scaling:
        p = scaling_snapshot()
        print(f"# wrote {p}")
        print(open(p).read())
    else:
        rows, derived = serve_scan_vs_python()
        for r in rows:
            print(r)
        print(json.dumps(derived))
