"""serve_scan_vs_python — serving-path tokens/sec and host roundtrips.

Measures the three serving paths on the reduced configs of three workload
families (dense LM, MoE, vision-frontend VLM), clean and under a registry
protection policy:

  * ``python`` — the legacy per-token dispatch loop (1 jit call per token),
  * ``scan``   — the fused ``lax.scan`` decode loop (1 jit call per
    generation; fault keys folded inside the scan),
  * ``sched``  — the continuous-batching scheduler on top of the fused
    chunked loop (per-request fault streams, bucketed prefill).

Reports tokens/sec (steady-state: compile excluded by a warmup call) and
host roundtrips (jitted executable invocations) per generation.  The scan
path must cut roundtrips by >=5x vs the python loop at equal (bit-identical
at temperature 0) outputs — that equality is enforced by
tests/test_serve_engine.py; this benchmark measures the speed side.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import ft
from repro.configs import get_config
from repro.models import build
from repro.serve.engine import Engine, ServeConfig
from repro.serve.scheduler import Request, Scheduler, SchedulerConfig

CONFIGS = (
    ("dense", "h2o-danube-1.8b"),
    ("moe", "qwen3-moe-235b-a22b"),
    ("vision", "paligemma-3b"),
)
POLICIES = (None, "crt3")
BATCH = 2
PROMPT = 8
NEW = 16
REPS = 2


def _policy(name):
    if name is None:
        return None
    # weight_faults=False: the per-request scheduler arm requires it (shared
    # ECC weight SRAM), and the arms must serve the same design
    return ft.get_policy(name, ber=1e-3, weight_faults=False)


def _batch_for(cfg, key):
    batch = {"tokens": jax.random.randint(key, (BATCH, PROMPT), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (BATCH, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    return batch


def _time_engine(model, params, policy, loop, batch):
    eng = Engine(model, params, cfg=ServeConfig(max_new_tokens=NEW),
                 policy=policy, loop=loop)
    jax.block_until_ready(eng.generate(batch, seed=0))     # compile
    t0 = time.perf_counter()
    for r in range(REPS):
        jax.block_until_ready(eng.generate(batch, seed=r))
    dt = time.perf_counter() - t0
    return (REPS * eng.stats.tokens) / dt, eng.stats.roundtrips


def _time_sched(model, params, policy, cfg):
    front = (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)

    def reqs():
        out = []
        for i in range(2 * BATCH):
            key = jax.random.PRNGKey(100 + i)
            toks = [int(t) for t in jax.random.randint(
                key, (PROMPT - (i % 3),), 0, cfg.vocab)]
            extras = None
            if cfg.frontend == "vision":
                extras = {"patch_embeds": jax.random.normal(
                    jax.random.fold_in(key, 1),
                    (front, cfg.d_model), jnp.bfloat16)}
            out.append(Request(rid=i, tokens=toks, max_new_tokens=NEW,
                               extras=extras))
        return out

    sched = Scheduler(model, params,
                      SchedulerConfig(max_batch=BATCH, buckets=(PROMPT,),
                                      max_new_tokens=NEW, decode_chunk=8),
                      policy=policy)
    sched.run(reqs())                                      # compile
    t0 = time.perf_counter()
    done = sched.run(reqs())
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.generated) for r in done.values())
    return n_tok / dt, sched.stats.roundtrips


def serve_scan_vs_python():
    rows = []
    ratios, uplifts = [], []
    for fam, arch in CONFIGS:
        cfg = get_config(arch, reduced=True)
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch_for(cfg, jax.random.PRNGKey(1))
        for pname in POLICIES:
            pol = _policy(pname)
            tps_py, rt_py = _time_engine(model, params, pol, "python", batch)
            tps_sc, rt_sc = _time_engine(model, params, pol, "scan", batch)
            tps_sd, rt_sd = _time_sched(model, params, pol, cfg)
            ratios.append(rt_py / rt_sc)
            uplifts.append(tps_sc / tps_py)
            rows.append(dict(
                family=fam, policy=pname or "clean",
                python_tok_s=round(tps_py, 1), scan_tok_s=round(tps_sc, 1),
                sched_tok_s=round(tps_sd, 1),
                python_roundtrips=rt_py, scan_roundtrips=rt_sc,
                sched_roundtrips=rt_sd,
                roundtrip_ratio=round(rt_py / rt_sc, 1),
                tok_s_uplift=round(tps_sc / tps_py, 2)))
    derived = dict(
        min_roundtrip_ratio=round(min(ratios), 1),
        min_tok_s_uplift=round(min(uplifts), 2),
        geomean_tok_s_uplift=round(
            float(jnp.exp(jnp.mean(jnp.log(jnp.asarray(uplifts))))), 2))
    return rows, derived


if __name__ == "__main__":
    import json
    rows, derived = serve_scan_vs_python()
    for r in rows:
        print(r)
    print(json.dumps(derived))
