"""dse_batched_vs_sequential — oracle wall-time per evaluated config.

Runs the same reduced Table-I DSE (VGG oracle, fixed seed) twice: once
sequentially (batch_size=1, one fault-injection executable compiled per
candidate structure it visits) and once batched (batch_size=8, candidates
share one vmapped executable via ``CnnOracle.accuracy_batch``).  Reports the
accuracy-oracle wall-time divided by the number of evaluated configs for
each mode — the number the batched engine exists to push down — plus the
best-config feasibility of both runs (they must agree).
"""
from __future__ import annotations

import time

import jax

from benchmarks.workloads import vgg16_gemms
from repro.core import bayesopt as B
from repro.core.evaluate import trained_cnn
from repro.core.pipeline import optimize

BER = 1e-3
SEED = 17
ITERS = 16
BATCH = 8


def _space():
    """Reduced Table-I space (the fig15 DSE grid)."""
    return [
        B.Param("s_th", (0.05, 0.1, 0.15, 0.2), monotone=+1),
        B.Param("ib_th", (2, 3, 4), monotone=+1),
        B.Param("nb_th", (1, 2, 3), monotone=+1),
        B.Param("q_scale", (4, 7, 10), monotone=0),
        B.Param("s_policy", ("uniform", "global"), monotone=0),
        B.Param("dot_size", (16, 52, 128), monotone=0),
        B.Param("data_reuse", (True, False), monotone=0),
        B.Param("pe_policy", ("configurable", "direct"), monotone=0),
    ]


def dse_batched_vs_sequential():
    o = trained_cnn("vgg")
    clean = o.accuracy(None)
    cons = B.Constraints(acc_min=0.94 * clean, perf_max=0.10, bw_max=0.10)
    layers = vgg16_gemms()

    rows = []
    per_cfg = {}
    feasible = {}
    for mode, batch in (("batched", BATCH), ("sequential", 1)):
        jax.clear_caches()  # neither mode inherits the other's executables
        timer = {"s": 0.0, "configs": 0}

        def acc_one(pol):
            t0 = time.perf_counter()
            a = o.accuracy(pol)
            timer["s"] += time.perf_counter() - t0
            timer["configs"] += 1
            return a

        def acc_many(pols):
            t0 = time.perf_counter()
            accs = o.accuracy_batch(pols)
            timer["s"] += time.perf_counter() - t0
            timer["configs"] += len(pols)
            return accs

        res = optimize(acc_one, layers, cons, BER, iter_max_step=ITERS,
                       seed=SEED, space=_space(), batch_size=batch,
                       acc_oracle_batch=acc_many if batch > 1 else None)
        us = 1e6 * timer["s"] / max(timer["configs"], 1)
        per_cfg[mode] = us
        feasible[mode] = res.dse.best is not None
        rows.append(dict(mode=mode, batch_size=batch,
                         configs=timer["configs"],
                         oracle_s=round(timer["s"], 2),
                         oracle_us_per_config=round(us, 0),
                         best_area=(None if res.area_overhead is None
                                    else round(res.area_overhead, 4)),
                         feasible=feasible[mode],
                         pruned=res.dse.pruned))
    derived = dict(
        speedup_per_config=round(per_cfg["sequential"] / per_cfg["batched"],
                                 2),
        sequential_us_per_config=round(per_cfg["sequential"], 0),
        batched_us_per_config=round(per_cfg["batched"], 0),
        feasibility_match=feasible["sequential"] == feasible["batched"],
    )
    return rows, derived
