"""Roofline analysis per (arch x shape) from the dry-run artifacts.

Three terms per cell (v5e numbers: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI):

  compute term    = FLOPs_per_device / 197e12
  memory term     = HBM_bytes_per_device / 819e9
  collective term = wire_bytes_per_device / 50e9

FLOPs and HBM bytes are ANALYTIC (model formulas below): XLA's
HloCostAnalysis visits while-loop bodies once, so compiled.cost_analysis()
undercounts scanned layers by ~n_layers x — we report it alongside as
hlo_flops with the MODEL/HLO ratio, per EXPERIMENTS.md.  Collective bytes are
parsed from the post-SPMD HLO with loop bodies multiplied by their trip
counts (repro.launch.dryrun.parse_collectives), bf16-adjusted for XLA:CPU's
f32 promotion.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.configs import ARCHS, SHAPES, get_config
from repro.models import build

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results")


def param_count(cfg):
    m = build(cfg)
    spec = m.param_specs()
    total = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(spec))
    active = total
    if cfg.moe:
        expert = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(spec)[0]:
            names = [getattr(k, "key", "") for k in path]
            if names[-1] in ("wi", "wg", "wo") and leaf.ndim == 4:
                expert += int(np.prod(leaf.shape))
        active = total - expert + expert * cfg.moe.top_k / cfg.moe.n_experts
    return total, active


def attn_flops(cfg, B, S, causal=True):
    """Forward attention score+value FLOPs for all layers."""
    if cfg.n_heads == 0:
        return 0.0
    kinds = list(cfg.block_pattern) * cfg.n_blocks + list(cfg.tail)
    tot = 0.0
    for k in kinds:
        if k in ("G", "E"):
            eff = S / 2 if causal else S
        elif k == "L":
            eff = min(cfg.window, S)
        else:
            continue
        tot += 4.0 * B * S * eff * cfg.n_heads * cfg.d_head
    if cfg.enc_dec:
        tot += cfg.n_enc_layers * 4.0 * B * S * S * cfg.n_heads * cfg.d_head
        tot += len(kinds) * 4.0 * B * S * S * cfg.n_kv_heads * cfg.d_head
    return tot


def model_flops(arch: str, shape_name: str, n_devices: int) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    total, active = param_count(cfg)
    if shape.kind == "train":
        D = B * S
        flops = 6.0 * active * D + 3.0 * attn_flops(cfg, B, S)
    elif shape.kind == "prefill":
        D = B * S
        flops = 2.0 * active * D + attn_flops(cfg, B, S)
    else:  # decode: one token, KV cache of S
        flops = 2.0 * active * B
        if cfg.n_heads:
            kinds = list(cfg.block_pattern) * cfg.n_blocks + list(cfg.tail)
            for k in kinds:
                eff = min(cfg.window, S) if k == "L" else S
                if k in ("G", "L"):
                    flops += 4.0 * B * eff * cfg.n_heads * cfg.d_head
    return dict(total_params=total, active_params=active,
                model_flops=flops, per_device_flops=flops / n_devices)


def hbm_bytes(arch: str, shape_name: str, n_devices: int,
              persistent: int, temp_tpu: int) -> float:
    """Per-device HBM traffic per step.

    train: params touched ~4x (fwd read, bwd read, grad write, opt rw of
    master+m+v) + saved residuals written+read + transient working set ~2x.
    prefill: params 1x + activations. decode: params 1x + cache read/write
    (the classic decode memory wall).
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    kind = shape.kind
    if kind == "train":
        return 4.0 * persistent + 2.0 * temp_tpu
    if kind == "prefill":
        return 1.0 * persistent + 2.0 * temp_tpu
    return 1.0 * persistent + temp_tpu  # decode


def load_cell(mesh: str, arch: str, shape: str):
    p = os.path.join(RESULTS, mesh, f"{arch}__{shape}.json")
    if not os.path.exists(p):
        return None
    return json.load(open(p))


def roofline_row(arch: str, shape: str, mesh: str = "single"):
    cell = load_cell(mesh, arch, shape)
    if cell is None or cell.get("skipped") or cell.get("failed"):
        return None
    n = cell["n_devices"]
    mf = model_flops(arch, shape, n)
    mem = cell["memory"]
    persistent = mem["persistent_bytes"]
    temp = mem["temp_bytes"] // 2  # bf16-adjusted (see dryrun docstring)
    hbm = hbm_bytes(arch, shape, n, persistent, temp)
    wire = cell["collectives"]["total_wire_bytes"]
    t_c = mf["per_device_flops"] / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_x = wire / LINK_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    hlo_flops = cell["cost"].get("flops", 0.0)
    return dict(
        arch=arch, shape=shape, mesh=mesh, n_devices=n,
        model_flops=mf["model_flops"],
        per_device_flops=mf["per_device_flops"],
        hlo_flops=hlo_flops,
        model_over_hlo=round(mf["per_device_flops"] / hlo_flops, 2)
        if hlo_flops else None,
        compute_s=t_c, memory_s=t_m, collective_s=t_x,
        dominant=dom,
        # no-overlap lower bound on MFU: compute / (all three serialized);
        # perfect overlap would give t_c / max(...) — we report the
        # pessimistic bound and hillclimb the non-compute terms
        roofline_frac=round(t_c / (t_c + t_m + t_x), 4)
        if (t_c + t_m + t_x) else 0.0,
        # for bandwidth-bound cells (decode): how close to the dominant
        # resource's roofline the step runs if nothing overlaps
        efficiency=round(max(t_c, t_m, t_x) / (t_c + t_m + t_x), 4)
        if (t_c + t_m + t_x) else 0.0,
        mem_gib=round(mem["per_device_total_tpu_est"] / 2 ** 30, 2),
        fits=mem["fits_16g"],
    )


def fused_decode_bytes(M: int, K: int, N: int, *, weight_faults: bool = True,
                       dppu: bool = True, per_row: bool = False) -> dict:
    """Analytic HBM bytes per protected decode-step linear (M, K) x (K, N):
    the composed three-dispatch pipeline vs the fused decode kernel.

    Composed (``kernels/fault_inject`` -> ``kernels/protected_mm``), per
    dispatch boundary everything round-trips through HBM:

      * weight fault injection: read int8 weights (K*N) + 8 uint32 random
        planes per element (32*K*N), write the faulty int8 copy (K*N);
      * protected matmul: read int8 activations (M*K) + the faulty weights
        again (K*N) + two 8-plane uint32 stacks for the output/DPPU fault
        streams (2 * 32*M*N) + the importance mask (4*N), write int8 out.

    Fused (one ``pallas_call``): activations + weights are read ONCE, the
    fault streams arrive as *packed* int32 flip words (4 bytes/element
    instead of 32), no intermediate tensor ever leaves VMEM, and the
    selected truncation LSB comes back as an (M, 1) int32 column.  Per-row
    weight faults add an (M, K, N) packed flip-word tensor (the per-request
    faulty-weight views are materialized nowhere).

    Arithmetic intensity uses the int-MAC count 2*M*K*N (DPPU recompute
    doubles it); decode is deeply memory-bound, so bytes saved translate
    ~1:1 into step time on the HBM roofline.
    """
    macs = 2.0 * M * K * N * (2 if dppu else 1)
    composed = (M * K + 2 * K * N + M * N          # int8 x, w x2, out
                + 4 * N                            # protect/importance mask
                + 64.0 * M * N)                    # 2 x 8 uint32 planes
    if weight_faults:
        composed += 32.0 * K * N + K * N           # weight planes + copy
    fused = (M * K + K * N + M * N + 4 * M        # int8 x, w, out; t column
             + 4.0 * M * N                         # packed output flip words
             + 4 * N)                              # importance row
    if dppu:
        fused += 4.0 * M * N                       # packed DPPU flip words
    if weight_faults:
        if per_row:
            fused += 4.0 * M * K * N               # per-row weight flip words
        else:
            fused += K * N                         # shared faulty copy read
    return dict(M=M, K=K, N=N, weight_faults=weight_faults, dppu=dppu,
                per_row=per_row, int_macs=macs,
                composed_bytes=composed, fused_bytes=fused,
                bytes_ratio=round(composed / fused, 2),
                composed_ai=round(macs / composed, 3),
                fused_ai=round(macs / fused, 3),
                ai_uplift=round((macs / fused) / (macs / composed), 2))


def fused_decode_table(shapes=((8, 2048, 2048), (8, 2048, 8192),
                               (8, 8192, 2048))):
    """Fused-vs-composed roofline movement over representative decode
    shapes (M = batch rows, K x N = projection)."""
    return [fused_decode_bytes(M, K, N) for M, K, N in shapes]


def full_table(mesh: str = "single"):
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if not cfg.supports(shape):
                rows.append(dict(arch=arch, shape=shape.name, mesh=mesh,
                                 skipped=True))
                continue
            r = roofline_row(arch, shape.name, mesh)
            if r:
                rows.append(r)
    return rows


def summarize(rows):
    done = [r for r in rows if not r.get("skipped")]
    compute_cells = [r for r in done if r["shape"] in ("train_4k",
                                                       "prefill_32k")]
    worst = min(compute_cells, key=lambda r: r["roofline_frac"])
    coll = max(done, key=lambda r: r["collective_s"]
               / max(r["compute_s"] + r["memory_s"], 1e-12))
    return dict(cells=len(done),
                all_fit=all(r["fits"] for r in done),
                mean_mfu_bound_train_prefill=round(float(np.mean(
                    [r["roofline_frac"] for r in compute_cells])), 4),
                mean_efficiency_all=round(float(np.mean(
                    [r["efficiency"] for r in done])), 4),
                worst_compute_cell=f"{worst['arch']}/{worst['shape']}"
                                   f" ({worst['roofline_frac']})",
                most_collective_bound=f"{coll['arch']}/{coll['shape']}")
