"""One function per paper table/figure.  Each returns (rows, derived) where
`derived` is the figure's headline number.

Fault rates: the paper's BER I = 1e-4 and II = 2e-4 target ImageNet-scale
models; our reduced CNNs see proportionally fewer bits per inference, so the
equivalent operating points (matched accuracy-degradation regime) are scaled
up.  The *relations* between strategies are the reproduction target.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.workloads import resnet50_gemms, vgg16_gemms
from repro.core import area as A
from repro.core import bayesopt as B
from repro.core import perfmodel as P
from repro.core import quantization as Q
from repro.core.evaluate import trained_cnn
from repro.core.pipeline import optimize
from repro.core.strategies import make_strategies
from repro.ft import get_policy

BER_I = 1e-3     # reduced-model operating point for the paper's fault I
BER_II = 2e-3    # ... and fault II
MODELS = ("vgg", "resnet")
WORKLOADS = {"vgg": vgg16_gemms(), "resnet": resnet50_gemms()}


def fig5_layer_sensitivity():
    rows = []
    spread = {}
    for mdl in MODELS:
        o = trained_cnn(mdl)
        for ber, tag in ((BER_I, "I"), (BER_II, "II")):
            sens = o.layer_sensitivity(ber)
            for layer, s in sens.items():
                rows.append(dict(model=mdl, fault=tag, layer=layer,
                                 sensitivity=round(s, 4)))
            vals = np.array(list(sens.values()))
            spread[(mdl, tag)] = float(vals.max() - vals.min())
    return rows, max(spread.values())


def fig6_cumulative_protection():
    rows = []
    for mdl in MODELS:
        o = trained_cnn(mdl)
        curve = o.cumulative_protection(BER_II)
        for i, (layer, acc) in enumerate(curve):
            rows.append(dict(model=mdl, n_protected=i, layer=layer,
                             acc=round(acc, 4)))
    return rows, rows[-1]["acc"] - rows[-len(curve)]["acc"]


def _dse_config(ber):
    """Small-space DSE optimum for the TMR-CL row (Table II analogue)."""
    return get_policy("cl", ber=ber, s_th=0.05,
                      ib_th=2 if ber == BER_I else 3,
                      nb_th=1, q_scale=7, dot_size=52)


def fig7_strategy_accuracy():
    rows = []
    strategies = make_strategies()
    for mdl in MODELS:
        o = trained_cnn(mdl)
        clean = o.accuracy(None)
        for ber, tag in ((BER_I, "I"), (BER_II, "II")):
            for name, s in strategies.items():
                ft = s.with_ber(ber)
                if name == "cl":
                    ft = _dse_config(ber)
                prot = None
                if name in ("arch", "alg"):
                    sens = o.layer_sensitivity(ber)
                    order = sorted(sens, key=sens.get, reverse=True)
                    prot = set(order[:max(1, int(0.4 * len(order)))])
                acc = o.accuracy(ft, protected_layers=prot)
                rows.append(dict(model=mdl, fault=tag, strategy=name,
                                 acc=round(acc, 4),
                                 drop=round(clean - acc, 4)))
    cl = [r for r in rows if r["strategy"] == "cl"]
    return rows, float(np.mean([r["drop"] for r in cl]))


def fig8_strategy_perf():
    rows = []
    for mdl in MODELS:
        layers = WORKLOADS[mdl]
        for name, s in make_strategies(_dse_config(BER_I)).items():
            loss = s.perf_loss(layers)
            rows.append(dict(model=mdl, strategy=name,
                             perf_loss=round(loss, 4)))
    cl = [r["perf_loss"] for r in rows if r["strategy"] == "cl"]
    return rows, float(np.mean(cl))


def fig9_strategy_area():
    rows = []
    for name, s in make_strategies(_dse_config(BER_I)).items():
        rows.append(dict(strategy=name,
                         rel_area=round(s.area_relative(), 4)))
    cl = next(r["rel_area"] for r in rows if r["strategy"] == "cl")
    return rows, cl


def fig10_neuron_bits():
    o = trained_cnn("resnet")
    rows = []
    combos = [(2, 1), (3, 1), (4, 1), (3, 2), (4, 2), (4, 3)]
    for s_th in (0.02, 0.05, 0.1, 0.25, 0.4):
        jax.clear_caches()  # each (s_th, ib, nb) is a distinct jit cache entry
        for ib, nb in combos:
            pol = get_policy("cl", ber=BER_II, s_th=s_th, ib_th=ib,
                             nb_th=nb, q_scale=7)
            acc = o.accuracy(pol)
            rows.append(dict(s_th=s_th, ib=ib, nb=nb, acc=round(acc, 4)))
    lo = np.mean([r["acc"] for r in rows if r["nb"] == 1])
    hi = np.mean([r["acc"] for r in rows if r["nb"] == 3])
    return rows, float(hi - lo)


def fig11_qscale():
    o = trained_cnn("resnet")
    rows = []
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 64))
    for qs in range(0, 15, 2):
        qe = float(Q.quant_error(x, qs))
        acc = o.accuracy(None) if qs == 0 else o.accuracy(
            get_policy("cl", ber=1e-9, q_scale=qs))
        rows.append(dict(q_scale=qs, quant_rel_err=round(qe, 5),
                         acc=round(acc, 4)))
    return rows, rows[4]["acc"] - rows[0]["acc"]  # drop at q_scale=8


def fig12_dppu_area():
    rows = []
    for dot in (8, 16, 32, 52, 64, 128, 256):
        for ib in (2, 3, 4):
            r = A.array_area(32, nb_th=1, q_scale=7,
                             pe_policy="configurable", dot_size=dot,
                             ib_th=ib)
            rows.append(dict(dot_size=dot, ib=ib,
                             overhead=round(r["overhead"], 4),
                             dppu_frac=round(r["dppu"] / r["total"], 4)))
    return rows, max(r["dppu_frac"] for r in rows)


def fig13_io_overhead():
    rows = []
    for mdl in MODELS:
        layers = WORKLOADS[mdl]
        dla = P.DlaConfig(array_dim=32, dot_size=52, data_reuse=True)
        for s_th in (0.02, 0.05, 0.08, 0.1, 0.2):
            io = P.io_bytes(layers, dla, "cl", s_th=s_th)
            rows.append(dict(model=mdl, s_th=s_th,
                             extra_io=round(io["extra_over_weights"], 4)))
    at_01 = np.mean([r["extra_io"] for r in rows if r["s_th"] == 0.1])
    return rows, float(at_01)


def fig14_bit_area():
    rows = []
    for s in (1, 2, 3):
        for policy in ("direct", "configurable"):
            for qs in (0, 4, 7):
                c = A.bit_protect_cost(s, qs, policy).total
                rows.append(dict(bits=s, policy=policy, q_scale=qs,
                                 extra_ge=round(c, 1),
                                 rel_pe=round(c / A.pe_cost(), 4)))
    red = []
    for s in (1, 2, 3):
        c7 = next(r["extra_ge"] for r in rows
                  if r["bits"] == s and r["policy"] == "configurable"
                  and r["q_scale"] == 7)
        d0 = next(r["extra_ge"] for r in rows
                  if r["bits"] == s and r["policy"] == "direct"
                  and r["q_scale"] == 0)
        red.append(1 - c7 / d0)
    return rows, float(np.mean(red))  # paper: 71.4%


def fig15_table2_dse():
    """Bayesian DSE for both fault rates; Pareto points + best config."""
    o = trained_cnn("vgg")
    clean = o.accuracy(None)
    layers = WORKLOADS["vgg"]
    rows = []
    best = {}
    for seed_base, (ber, tag, margin) in enumerate(
            ((BER_I, "I", 0.97), (BER_II, "II", 0.95))):
        cons = B.Constraints(acc_min=margin * clean, perf_max=0.10,
                             bw_max=0.10)
        space = [
            B.Param("s_th", (0.05, 0.1, 0.15, 0.2), monotone=+1),
            B.Param("ib_th", (2, 3, 4), monotone=+1),
            B.Param("nb_th", (1, 2, 3), monotone=+1),
            B.Param("q_scale", (4, 7, 10), monotone=0),
            B.Param("s_policy", ("uniform", "global"), monotone=0),
            B.Param("dot_size", (16, 52, 128), monotone=0),
            B.Param("data_reuse", (True, False), monotone=0),
            B.Param("pe_policy", ("configurable", "direct"), monotone=0),
        ]
        def acc_oracle(ft):
            jax.clear_caches()  # every DSE point is a fresh static config
            return o.accuracy(ft)

        res = optimize(acc_oracle, layers, cons, ber,
                       iter_max_step=24, seed=17 + seed_base, space=space)
        for cfgd, ev in res.dse.history:
            rows.append(dict(fault=tag, area=round(ev.area, 4),
                             acc=round(ev.acc, 4),
                             feasible=ev.feasible(cons), **{
                                 k: str(v) for k, v in cfgd.items()}))
        best[tag] = dict(res.dse.best or {}, area=res.area_overhead,
                         pruned=res.dse.pruned, evals=res.dse.evaluations)
    return rows, best
