"""Fault-aware training (FAT) benchmarks.

fat_vs_baseline — the headline claim: a CNN trained *through* injected
faults (``train_cnn(fat=...)``, straight-through gradients on the bit-exact
faulty datapath) holds more accuracy under deployment-time faults than the
same architecture trained clean, at matched clean accuracy.  Reports
accuracy-under-fault across a BER sweep for both networks plus the margin
at the training operating point.

fat_dse — the cross-layer payoff: running the Bayesian DSE over Table I
*plus* the ``fat_ber`` training axis (``fat_table1_space``) finds a feasible
config with less protection hardware than the DSE restricted to
``fat_ber=0``, because training-time hardening substitutes for area.
"""
from __future__ import annotations

import jax

from benchmarks.workloads import vgg16_gemms
from repro.core import bayesopt as B
from repro.core.evaluate import FatCnnOracle, trained_cnn, trained_cnn_fat
from repro.ft import get_policy

TRAIN_STEPS = 250
FAT_BER = 2e-3
BER_SWEEP = (5e-4, 1e-3, 2e-3, 4e-3)


def fat_vs_baseline():
    base = trained_cnn("vgg", TRAIN_STEPS)
    fat = trained_cnn_fat("vgg", TRAIN_STEPS, FAT_BER)
    rows = [("clean", base.clean_acc, fat.clean_acc)]
    margin = {}
    for ber in BER_SWEEP:
        pol = get_policy("cl", ber=ber)
        a_base = base.accuracy(pol)
        a_fat = fat.accuracy(pol)
        rows.append((f"ber={ber:g}", a_base, a_fat))
        margin[ber] = a_fat - a_base
    derived = {"clean_base": round(base.clean_acc, 4),
               "clean_fat": round(fat.clean_acc, 4),
               "margin_at_fat_ber": round(margin.get(FAT_BER, 0.0), 4),
               "margin_at_2x": round(margin.get(2 * FAT_BER, 0.0), 4)}
    return [list(r) for r in rows], derived


def _fat_space(fat_bers):
    """Reduced Table-I grid (the dse_batch one) + the training axis."""
    return [
        B.Param("s_th", (0.05, 0.1, 0.15, 0.2), monotone=+1),
        B.Param("ib_th", (2, 3, 4), monotone=+1),
        B.Param("nb_th", (1, 2, 3), monotone=+1),
        B.Param("q_scale", (4, 7, 10), monotone=0),
        B.Param("s_policy", ("uniform", "global"), monotone=0),
        B.Param("dot_size", (16, 52, 128), monotone=0),
        B.Param("data_reuse", (True, False), monotone=0),
        B.Param("pe_policy", ("configurable", "direct"), monotone=0),
        B.Param("fat_ber", tuple(fat_bers), monotone=0),
    ]


def fat_dse():
    from repro.core.pipeline import optimize

    oracle = FatCnnOracle("vgg", TRAIN_STEPS)
    clean = oracle.oracle(0.0).accuracy(None)
    cons = B.Constraints(acc_min=0.94 * clean, perf_max=0.10, bw_max=0.10)
    layers = vgg16_gemms()
    rows = []
    best = {}
    for mode, fat_bers in (("clean_trained", (0.0,)),
                           ("fat_axis", (0.0, FAT_BER))):
        jax.clear_caches()
        res = optimize(oracle, layers, cons, ber=FAT_BER,
                       iter_max_step=16, seed=17, batch_size=8,
                       space=_fat_space(fat_bers),
                       acc_oracle_batch=oracle.batch)
        area = res.area_overhead
        best[mode] = area
        rows.append([mode, res.dse.best, area])
    derived = {"area_clean_trained": best["clean_trained"],
               "area_fat_axis": best["fat_axis"]}
    return rows, derived
